//! `hwsplit serve --shards N` — the multi-process supervisor/router that
//! scales serving past one process.
//!
//! One process owning every workload serializes on a single
//! [`super::SessionStore`] and accept path; this module shards that work
//! across N **child daemons of the same binary** and keeps the wire
//! protocol identical, so clients cannot tell a sharded deployment from a
//! single process:
//!
//! * **Partitioning** ([`partition_workloads`]): workload names are
//!   ordered by `(fx-hash, name)` and dealt round-robin, so the
//!   assignment is stable across restarts and independent of the
//!   `--snapshots` argument order. Each shard is spawned with exactly its
//!   subset of snapshot files.
//! * **Supervision**: children are spawned with `--port 0` (their bound
//!   address is parsed from the `listening on <addr>` startup line),
//!   health-checked by `ping` every [`HEALTH_INTERVAL`], and restarted
//!   with exponential backoff when they crash or stop answering — fault
//!   tolerance the single process cannot have. Child stdout is relayed to
//!   the supervisor's stderr under a `[shard i]` prefix.
//! * **Routing**: the router answers `ping` locally, forwards each
//!   `query` verbatim to the shard owning its workload (pass-through
//!   proxying of the request and response lines, so routed responses are
//!   byte-identical to single-process ones — including typed
//!   `busy`/`timeout` errors produced by the owning child), fans `stats`
//!   out to every shard and aggregates, and broadcasts `reload` /
//!   `shutdown`. Anything unroutable — unparseable JSON, unknown
//!   commands, a query without a known workload — is forwarded to shard
//!   0, which both renders the identical typed error *and* counts it, so
//!   aggregate counters stay a pure per-shard sum.
//! * **Degradation**: a request hitting a shard that is mid-restart
//!   answers a typed `busy` error with a `retry_after_ms` hint (counted
//!   in the router-local `router_errors` stat, never in the per-shard
//!   sums).
//!
//! `stats` aggregation semantics (pinned by `rust/tests/serving_sharded.rs`
//! and documented in `docs/serving.md`): counters and `queries_per_sec`
//! are exact sums, `p50_ms`/`p99_ms` are the max across shards (a
//! conservative bound — true percentiles would need raw latencies on the
//! wire), `generation` is the min (every shard has seen at least that
//! many reloads), plus router-only fields: `shards`, `restarts`,
//! `router_errors`, `shard_generations`, `shard_pids`.

use super::json::Json;
use super::protocol::{error_response, ok_response, Command, ErrorCode};
use crate::error::{Error, Result};
use crate::fx::FxHasher;
use crate::persist;
use crate::report::JsonValue;
use std::collections::{BTreeMap, BTreeSet, HashMap};
use std::hash::Hasher;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::process::{Child, Command as Process, Stdio};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// How often the supervisor health-checks every child.
const HEALTH_INTERVAL: Duration = Duration::from_millis(250);
/// Consecutive failed pings tolerated on a still-running child before it
/// is declared wedged and restarted (a crashed child restarts at once).
const PING_FAIL_LIMIT: u32 = 3;
/// Bound on connecting to a shard (proxying and pinging).
const PROXY_CONNECT_TIMEOUT: Duration = Duration::from_millis(1_000);
/// Bound on the ping round-trip's read/write halves.
const PING_IO_TIMEOUT: Duration = Duration::from_millis(1_000);
/// `retry_after_ms` hint on the router's shard-unavailable `busy` answer:
/// roughly one restart backoff step.
const RESTART_HINT_MS: i64 = 500;
/// How long a shutdown broadcast waits for a child before killing it.
const REAP_TIMEOUT: Duration = Duration::from_secs(5);

/// Stable workload→shard assignment: order names by `(fx-hash, name)` and
/// deal round-robin. Deterministic, independent of input order, and
/// balanced to within one workload per shard.
pub fn partition_workloads<T: AsRef<str>>(names: &[T], shards: usize) -> Vec<Vec<String>> {
    let shards = shards.max(1);
    let mut ordered: Vec<(u64, &str)> =
        names.iter().map(|n| (fx_str(n.as_ref()), n.as_ref())).collect();
    ordered.sort_unstable();
    let mut groups = vec![Vec::new(); shards];
    for (i, (_, name)) in ordered.into_iter().enumerate() {
        groups[i % shards].push(name.to_string());
    }
    groups
}

fn fx_str(s: &str) -> u64 {
    let mut h = FxHasher::default();
    h.write(s.as_bytes());
    h.finish()
}

fn join_u64s(vals: &[u64]) -> String {
    vals.iter().map(|v| v.to_string()).collect::<Vec<_>>().join(",")
}

/// Supervisor knobs. `child_args` is appended verbatim to every child's
/// `serve` invocation (worker counts, queue depth, timeouts, …).
#[derive(Debug, Clone)]
pub struct ShardConfig {
    /// The binary to spawn shards from — the CLI passes
    /// `std::env::current_exe()`, tests pass `env!("CARGO_BIN_EXE_hwsplit")`.
    pub program: PathBuf,
    /// Requested shard count; capped at the number of distinct workloads
    /// so no child is spawned empty.
    pub shards: usize,
    /// Host children bind to (they always take `--port 0`).
    pub host: String,
    /// The children's `--request-timeout-ms`; the router's proxy read
    /// deadline is this plus a margin (30 s when deadlines are disabled).
    pub request_timeout_ms: u64,
    /// Extra flags forwarded to every child's `serve` command line.
    pub child_args: Vec<String>,
}

impl ShardConfig {
    pub fn new(program: impl Into<PathBuf>, shards: usize) -> ShardConfig {
        ShardConfig {
            program: program.into(),
            shards,
            host: "127.0.0.1".to_string(),
            request_timeout_ms: 10_000,
            child_args: Vec::new(),
        }
    }
}

/// One child daemon: its current address and process handle. Replaced
/// wholesale on restart (the address changes — children bind port 0).
struct ShardSlot {
    addr: SocketAddr,
    child: Child,
}

/// Everything needed to (re)spawn one shard.
struct ShardSpec {
    index: usize,
    program: PathBuf,
    args: Vec<String>,
}

/// The supervisor: owns the router listener, the child processes, and the
/// health-check/restart loop. Constructed via [`ShardServer::bind`] (which
/// spawns the children), driven by [`ShardServer::run`].
pub struct ShardServer {
    listener: TcpListener,
    shutdown: Arc<AtomicBool>,
    slots: Arc<Vec<Mutex<ShardSlot>>>,
    specs: Arc<Vec<ShardSpec>>,
    route: Arc<HashMap<String, usize>>,
    restarts: Arc<AtomicUsize>,
    router_errors: Arc<AtomicUsize>,
    config: ShardConfig,
}

/// The per-connection router state: shared slots/routing plus counters.
#[derive(Clone)]
struct RouterCtx {
    slots: Arc<Vec<Mutex<ShardSlot>>>,
    route: Arc<HashMap<String, usize>>,
    shutdown: Arc<AtomicBool>,
    restarts: Arc<AtomicUsize>,
    router_errors: Arc<AtomicUsize>,
    request_timeout_ms: u64,
    listener_addr: SocketAddr,
}

impl ShardServer {
    /// Bind the router on `addr`, partition `snapshots` by the workload
    /// each header names, and spawn one child daemon per shard. Fails —
    /// with already-spawned children reaped — if any snapshot header is
    /// unreadable or any child dies during startup.
    pub fn bind(addr: &str, snapshots: &[String], config: ShardConfig) -> Result<ShardServer> {
        let mut by_workload: HashMap<String, String> = HashMap::new();
        for path in snapshots {
            let meta = persist::peek_header(path)?;
            by_workload.insert(meta.workload, path.clone());
        }
        if by_workload.is_empty() {
            return Err(Error::InvalidConfig("sharded serve needs at least one snapshot".into()));
        }
        let names: Vec<String> = by_workload.keys().cloned().collect();
        let groups = partition_workloads(&names, config.shards.clamp(1, by_workload.len()));
        let mut route = HashMap::new();
        let mut specs = Vec::new();
        for (i, group) in groups.iter().enumerate() {
            for w in group {
                route.insert(w.clone(), i);
            }
            let paths: Vec<String> = group.iter().map(|w| by_workload[w].clone()).collect();
            let mut args = vec![
                "serve".to_string(),
                "--snapshots".to_string(),
                paths.join(","),
                "--host".to_string(),
                config.host.clone(),
                "--port".to_string(),
                "0".to_string(),
            ];
            args.extend(config.child_args.iter().cloned());
            specs.push(ShardSpec { index: i, program: config.program.clone(), args });
        }
        let listener = TcpListener::bind(addr)?;
        let mut slots = Vec::with_capacity(specs.len());
        for spec in &specs {
            match spawn_shard(spec) {
                Ok(slot) => slots.push(Mutex::new(slot)),
                Err(e) => {
                    for slot in &slots {
                        let mut s = slot.lock().unwrap();
                        let _ = s.child.kill();
                        let _ = s.child.wait();
                    }
                    return Err(e);
                }
            }
        }
        Ok(ShardServer {
            listener,
            shutdown: Arc::new(AtomicBool::new(false)),
            slots: Arc::new(slots),
            specs: Arc::new(specs),
            route: Arc::new(route),
            restarts: Arc::new(AtomicUsize::new(0)),
            router_errors: Arc::new(AtomicUsize::new(0)),
            config,
        })
    }

    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// How many child daemons this supervisor runs (the requested shard
    /// count capped at the distinct-workload count).
    pub fn shard_count(&self) -> usize {
        self.slots.len()
    }

    /// Which shard owns `workload` (None for unregistered names — the
    /// router forwards those to shard 0 for the typed error).
    pub fn shard_of(&self, workload: &str) -> Option<usize> {
        self.route.get(workload).copied()
    }

    /// Current child addresses (a restart changes the restarted shard's).
    pub fn shard_addrs(&self) -> Vec<SocketAddr> {
        self.slots.iter().map(|s| s.lock().unwrap().addr).collect()
    }

    /// Current child process ids.
    pub fn shard_pids(&self) -> Vec<u32> {
        self.slots.iter().map(|s| s.lock().unwrap().child.id()).collect()
    }

    /// How many child restarts the health loop has performed.
    pub fn restarts(&self) -> usize {
        self.restarts.load(Ordering::SeqCst)
    }

    /// Router-local failures (shard unreachable while proxying) — kept
    /// out of the per-shard sums so those aggregate exactly.
    pub fn router_errors(&self) -> usize {
        self.router_errors.load(Ordering::SeqCst)
    }

    /// Kill one child outright (fault-injection hook for tests and the CI
    /// smoke script — the health loop notices and restarts it).
    pub fn kill_shard(&self, shard: usize) -> Result<()> {
        let slot = self
            .slots
            .get(shard)
            .ok_or_else(|| Error::InvalidConfig(format!("no shard {shard}")))?;
        let mut s = slot.lock().unwrap();
        s.child.kill().map_err(|e| Error::Io(format!("kill shard {shard}: {e}")))?;
        let _ = s.child.wait();
        Ok(())
    }

    /// Ask the router to stop, nudging it out of `accept()`. Children are
    /// shut down and reaped by [`ShardServer::run`] on its way out.
    pub fn request_shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
        if let Ok(addr) = self.listener.local_addr() {
            let _ = TcpStream::connect(addr);
        }
    }

    /// Run the supervisor until shutdown (client `{"cmd":"shutdown"}` or
    /// [`ShardServer::request_shutdown`]): spawn the health/restart loop
    /// and accept router connections. On exit the health loop is joined
    /// first (so nothing restarts a child mid-teardown), then shutdown is
    /// broadcast and every child reaped — by force after [`REAP_TIMEOUT`].
    pub fn run(&self) -> Result<()> {
        let ctx = self.router_ctx()?;
        let health = {
            let slots = self.slots.clone();
            let specs = self.specs.clone();
            let shutdown = self.shutdown.clone();
            let restarts = self.restarts.clone();
            std::thread::spawn(move || health_loop(&slots, &specs, &shutdown, &restarts))
        };
        let result = self.accept_loop(&ctx);
        self.shutdown.store(true, Ordering::SeqCst);
        let _ = health.join();
        self.shutdown_children();
        result
    }

    fn accept_loop(&self, ctx: &RouterCtx) -> Result<()> {
        let mut err_streak = 0u32;
        for stream in self.listener.incoming() {
            if self.shutdown.load(Ordering::SeqCst) {
                break;
            }
            let stream = match stream {
                Ok(s) => {
                    err_streak = 0;
                    s
                }
                Err(e) => {
                    err_streak += 1;
                    if err_streak >= super::MAX_ACCEPT_ERROR_STREAK {
                        return Err(Error::Io(format!(
                            "router accept loop failing persistently ({err_streak} errors): {e}"
                        )));
                    }
                    std::thread::sleep(Duration::from_millis(10));
                    continue;
                }
            };
            let ctx = ctx.clone();
            std::thread::spawn(move || {
                let _ = route_connection(stream, &ctx);
            });
        }
        Ok(())
    }

    fn router_ctx(&self) -> Result<RouterCtx> {
        Ok(RouterCtx {
            slots: self.slots.clone(),
            route: self.route.clone(),
            shutdown: self.shutdown.clone(),
            restarts: self.restarts.clone(),
            router_errors: self.router_errors.clone(),
            request_timeout_ms: self.config.request_timeout_ms,
            listener_addr: self.listener.local_addr()?,
        })
    }

    /// Broadcast `shutdown` to every child, then reap: wait up to
    /// [`REAP_TIMEOUT`] for a clean exit before killing.
    fn shutdown_children(&self) {
        for slot in self.slots.iter() {
            let addr = slot.lock().unwrap().addr;
            let _ = proxy_io(addr, "{\"cmd\":\"shutdown\"}", 1_000);
        }
        for (i, slot) in self.slots.iter().enumerate() {
            let mut s = slot.lock().unwrap();
            let deadline = Instant::now() + REAP_TIMEOUT;
            loop {
                match s.child.try_wait() {
                    Ok(Some(_)) => break,
                    Ok(None) if Instant::now() < deadline => {
                        std::thread::sleep(Duration::from_millis(50));
                    }
                    _ => {
                        let _ = s.child.kill();
                        let _ = s.child.wait();
                        eprintln!("serve: shard {i} did not exit in time; killed");
                        break;
                    }
                }
            }
        }
    }
}

/// Spawn one child daemon and wait for it to announce its address: lines
/// before `listening on <addr>` (snapshot registration) are relayed to
/// stderr under a `[shard i]` prefix, as is everything after (from a
/// background drain thread). Fails if the child exits first.
fn spawn_shard(spec: &ShardSpec) -> Result<ShardSlot> {
    let mut child = Process::new(&spec.program)
        .args(&spec.args)
        .stdin(Stdio::null())
        .stdout(Stdio::piped())
        .stderr(Stdio::inherit())
        .spawn()
        .map_err(|e| Error::Io(format!("spawn shard {}: {e}", spec.index)))?;
    let stdout = child.stdout.take().expect("stdout piped above");
    let mut reader = BufReader::new(stdout);
    let index = spec.index;
    let addr = loop {
        let mut line = String::new();
        let n = reader
            .read_line(&mut line)
            .map_err(|e| Error::Io(format!("read shard {index} startup output: {e}")))?;
        if n == 0 {
            let status = child.wait().map(|s| s.to_string()).unwrap_or_else(|e| e.to_string());
            return Err(Error::Io(format!("shard {index} exited during startup ({status})")));
        }
        if let Some(rest) = line.split("listening on ").nth(1) {
            let token = rest.split_whitespace().next().unwrap_or("");
            break token.parse::<SocketAddr>().map_err(|e| {
                Error::Io(format!("shard {index} announced a bad address '{token}': {e}"))
            })?;
        }
        eprintln!("[shard {index}] {}", line.trim_end());
    };
    std::thread::spawn(move || {
        for line in reader.lines().map_while(std::io::Result::ok) {
            eprintln!("[shard {index}] {line}");
        }
    });
    Ok(ShardSlot { addr, child })
}

/// The supervisor's health/restart loop: every [`HEALTH_INTERVAL`], check
/// each child for exit (`try_wait`) and liveness (`ping`). A crashed
/// child restarts immediately; a live-but-unresponsive one is given
/// [`PING_FAIL_LIMIT`] strikes. Restarts back off exponentially
/// (100 ms · 2^strikes, capped at 5 s) so a crash-looping child cannot
/// busy-spin the supervisor. The backoff sleeps **outside** the slot lock
/// — the router keeps failing fast (typed `busy`) meanwhile.
fn health_loop(
    slots: &[Mutex<ShardSlot>],
    specs: &[ShardSpec],
    shutdown: &AtomicBool,
    restarts: &AtomicUsize,
) {
    let mut fails = vec![0u32; slots.len()];
    while !shutdown.load(Ordering::SeqCst) {
        std::thread::sleep(HEALTH_INTERVAL);
        for (i, slot) in slots.iter().enumerate() {
            if shutdown.load(Ordering::SeqCst) {
                return;
            }
            let (addr, exited) = {
                let mut s = slot.lock().unwrap();
                (s.addr, matches!(s.child.try_wait(), Ok(Some(_))))
            };
            if !exited && ping_ok(addr) {
                fails[i] = 0;
                continue;
            }
            fails[i] += 1;
            if !exited && fails[i] < PING_FAIL_LIMIT {
                continue; // tolerate a transient ping miss on a live child
            }
            let backoff = Duration::from_millis((100u64 << fails[i].min(6)).min(5_000));
            std::thread::sleep(backoff);
            if shutdown.load(Ordering::SeqCst) {
                return;
            }
            match spawn_shard(&specs[i]) {
                Ok(fresh) => {
                    let fresh_addr = fresh.addr;
                    let mut s = slot.lock().unwrap();
                    let _ = s.child.kill();
                    let _ = s.child.wait();
                    *s = fresh;
                    drop(s);
                    restarts.fetch_add(1, Ordering::SeqCst);
                    fails[i] = 0;
                    eprintln!("serve: restarted shard {i} on {fresh_addr}");
                }
                Err(e) => eprintln!("serve: shard {i} restart failed ({e}); retrying"),
            }
        }
    }
}

/// One ping round-trip against a shard, fully time-bounded.
fn ping_ok(addr: SocketAddr) -> bool {
    let Ok(mut stream) = TcpStream::connect_timeout(&addr, PROXY_CONNECT_TIMEOUT) else {
        return false;
    };
    let _ = stream.set_write_timeout(Some(PING_IO_TIMEOUT));
    let _ = stream.set_read_timeout(Some(PING_IO_TIMEOUT));
    if stream.write_all(b"{\"cmd\":\"ping\"}\n").is_err() {
        return false;
    }
    let mut line = String::new();
    let mut reader = BufReader::new(stream);
    matches!(reader.read_line(&mut line), Ok(n) if n > 0) && line.contains("\"pong\":true")
}

/// Serve one router connection: same line-loop shape as the single-process
/// daemon (polling reads observe shutdown; partial lines survive polls).
fn route_connection(stream: TcpStream, ctx: &RouterCtx) -> std::io::Result<()> {
    stream.set_read_timeout(Some(super::POLL_INTERVAL))?;
    stream.set_write_timeout(Some(Duration::from_millis(10_000)))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    let mut line = String::new();
    loop {
        match reader.read_line(&mut line) {
            Ok(0) => return Ok(()), // client closed
            Ok(_) => {}
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                if ctx.shutdown.load(Ordering::SeqCst) {
                    return Ok(());
                }
                continue; // idle poll; `line` keeps any partial request
            }
            Err(e) => return Err(e),
        }
        let trimmed = line.trim();
        if !trimmed.is_empty() {
            let (response, stop) = route_line(trimmed, ctx);
            writer.write_all(response.as_bytes())?;
            writer.write_all(b"\n")?;
            writer.flush()?;
            if stop {
                ctx.shutdown.store(true, Ordering::SeqCst);
                let _ = TcpStream::connect(ctx.listener_addr); // nudge the acceptor
                return Ok(());
            }
        }
        line.clear();
        if ctx.shutdown.load(Ordering::SeqCst) {
            return Ok(());
        }
    }
}

/// Route one request line. Pass-through proxying keeps routed responses
/// byte-identical to a single process (the serving_sharded tests pin
/// this); unroutable lines go to shard 0 so exactly one shard renders
/// *and counts* the typed error.
fn route_line(line: &str, ctx: &RouterCtx) -> (String, bool) {
    let req = match Json::parse(line) {
        Ok(req) => req,
        Err(_) => return (forward(ctx, 0, line), false),
    };
    let cmd_name = req.get("cmd").and_then(Json::as_str).unwrap_or("query");
    match Command::parse(cmd_name) {
        None => (forward(ctx, 0, line), false),
        Some(Command::Ping) => ("{\"ok\":true,\"pong\":true}".to_string(), false),
        Some(Command::Shutdown) => ("{\"ok\":true,\"shutting_down\":true}".to_string(), true),
        Some(Command::Stats) => (aggregate_stats(ctx), false),
        Some(Command::Reload) => (broadcast_reload(ctx), false),
        Some(Command::Query) => {
            let shard = req
                .get("workload")
                .and_then(Json::as_str)
                .and_then(|w| ctx.route.get(w).copied())
                .unwrap_or(0);
            (forward(ctx, shard, line), false)
        }
    }
}

/// Proxy a line to a shard, collapsing proxy failure into its rendered
/// `busy` response.
fn forward(ctx: &RouterCtx, shard: usize, line: &str) -> String {
    match proxy_to(ctx, shard, line) {
        Ok(resp) | Err(resp) => resp,
    }
}

/// Proxy one request line to `shard`. `Err` carries the fully rendered
/// router response for an unreachable shard: a typed `busy` with a retry
/// hint (the shard is most likely mid-restart), counted in
/// `router_errors` so per-shard counter sums stay exact.
fn proxy_to(ctx: &RouterCtx, shard: usize, line: &str) -> std::result::Result<String, String> {
    let addr = ctx.slots[shard].lock().unwrap().addr;
    match proxy_io(addr, line, ctx.request_timeout_ms) {
        Ok(resp) => Ok(resp),
        Err(e) => {
            ctx.router_errors.fetch_add(1, Ordering::SeqCst);
            let msg = format!("shard {shard} is unavailable ({e}); retry shortly");
            Err(error_response(
                ErrorCode::Busy,
                &msg,
                &[("retry_after_ms", JsonValue::Int(RESTART_HINT_MS))],
            ))
        }
    }
}

/// One request/response round-trip against a shard address, every phase
/// time-bounded. The read deadline is the children's request timeout plus
/// a margin (30 s when deadlines are disabled) — the child's own typed
/// `timeout` answer arrives well within it.
fn proxy_io(addr: SocketAddr, line: &str, timeout_ms: u64) -> std::io::Result<String> {
    let mut stream = TcpStream::connect_timeout(&addr, PROXY_CONNECT_TIMEOUT)?;
    stream.set_write_timeout(Some(PROXY_CONNECT_TIMEOUT))?;
    let wait = if timeout_ms == 0 { 30_000 } else { timeout_ms + 2_000 };
    stream.set_read_timeout(Some(Duration::from_millis(wait)))?;
    stream.write_all(line.as_bytes())?;
    stream.write_all(b"\n")?;
    let mut reader = BufReader::new(stream);
    let mut resp = String::new();
    let n = reader.read_line(&mut resp)?;
    if n == 0 {
        return Err(std::io::Error::new(
            std::io::ErrorKind::UnexpectedEof,
            "shard closed the connection",
        ));
    }
    while resp.ends_with('\n') || resp.ends_with('\r') {
        resp.pop();
    }
    Ok(resp)
}

/// Fan `stats` out to every shard and aggregate: exact sums for counters
/// and `queries_per_sec`, max for the latency percentiles (conservative),
/// min for `generation`, union for workloads, per-workload sums — plus
/// the router-only `shards`/`restarts`/`router_errors`/`shard_generations`
/// /`shard_pids` fields. A shard failure relays that shard's (or the
/// router's `busy`) response instead.
fn aggregate_stats(ctx: &RouterCtx) -> String {
    let mut replies = Vec::with_capacity(ctx.slots.len());
    for shard in 0..ctx.slots.len() {
        match proxy_to(ctx, shard, "{\"cmd\":\"stats\"}") {
            Ok(line) => match Json::parse(&line) {
                Ok(j) if j.get("ok").and_then(Json::as_bool) == Some(true) => replies.push(j),
                _ => return line,
            },
            Err(resp) => return resp,
        }
    }
    let sum = |key: &str| -> i64 {
        replies.iter().map(|j| j.get(key).and_then(Json::as_u64).unwrap_or(0) as i64).sum()
    };
    let fmax = |key: &str| -> f64 {
        replies.iter().filter_map(|j| j.get(key).and_then(Json::as_f64)).fold(f64::NAN, f64::max)
    };
    let qps: f64 =
        replies.iter().filter_map(|j| j.get("queries_per_sec").and_then(Json::as_f64)).sum();
    let generations: Vec<u64> =
        replies.iter().map(|j| j.get("generation").and_then(Json::as_u64).unwrap_or(0)).collect();
    let min_gen = generations.iter().copied().min().unwrap_or(0);
    let mut workloads = BTreeSet::new();
    let mut by_workload: BTreeMap<String, u64> = BTreeMap::new();
    for j in &replies {
        for w in j.get("workloads").and_then(Json::as_str).unwrap_or("").split(',') {
            if !w.is_empty() {
                workloads.insert(w.to_string());
            }
        }
        for entry in j.get("served_by_workload").and_then(Json::as_str).unwrap_or("").split(',') {
            if let Some((w, n)) = entry.rsplit_once('=') {
                *by_workload.entry(w.to_string()).or_insert(0) += n.parse::<u64>().unwrap_or(0);
            }
        }
    }
    let entries: Vec<String> = by_workload.into_iter().map(|(w, n)| format!("{w}={n}")).collect();
    let served_by_workload = entries.join(",");
    let pids: Vec<u64> = ctx.slots.iter().map(|s| s.lock().unwrap().child.id() as u64).collect();
    let fields = [
        ("served", JsonValue::Int(sum("served"))),
        ("errors", JsonValue::Int(sum("errors"))),
        ("rejected", JsonValue::Int(sum("rejected"))),
        ("timeouts", JsonValue::Int(sum("timeouts"))),
        ("reloads", JsonValue::Int(sum("reloads"))),
        ("queue_depth", JsonValue::Int(sum("queue_depth"))),
        ("queries_per_sec", JsonValue::Num(qps)),
        ("p50_ms", JsonValue::Num(fmax("p50_ms"))),
        ("p99_ms", JsonValue::Num(fmax("p99_ms"))),
        ("cached_sessions", JsonValue::Int(sum("cached_sessions"))),
        ("generation", JsonValue::Int(min_gen as i64)),
        ("workloads", JsonValue::Str(workloads.into_iter().collect::<Vec<_>>().join(","))),
        ("served_by_workload", JsonValue::Str(served_by_workload)),
        ("shards", JsonValue::Int(ctx.slots.len() as i64)),
        ("restarts", JsonValue::Int(ctx.restarts.load(Ordering::SeqCst) as i64)),
        ("router_errors", JsonValue::Int(ctx.router_errors.load(Ordering::SeqCst) as i64)),
        ("shard_generations", JsonValue::Str(join_u64s(&generations))),
        ("shard_pids", JsonValue::Str(join_u64s(&pids))),
    ];
    ok_response(&fields)
}

/// Broadcast `reload` to every shard. All-ok answers aggregate like the
/// single-process response (union of reloaded names, min generation); any
/// shard failure relays that shard's response verbatim.
fn broadcast_reload(ctx: &RouterCtx) -> String {
    let mut names = BTreeSet::new();
    let mut min_gen = u64::MAX;
    for shard in 0..ctx.slots.len() {
        match proxy_to(ctx, shard, "{\"cmd\":\"reload\"}") {
            Ok(line) => match Json::parse(&line) {
                Ok(j) if j.get("ok").and_then(Json::as_bool) == Some(true) => {
                    for w in j.get("reloaded").and_then(Json::as_str).unwrap_or("").split(',') {
                        if !w.is_empty() {
                            names.insert(w.to_string());
                        }
                    }
                    min_gen = min_gen.min(j.get("generation").and_then(Json::as_u64).unwrap_or(0));
                }
                _ => return line,
            },
            Err(resp) => return resp,
        }
    }
    let fields = [
        ("reloaded", JsonValue::Str(names.into_iter().collect::<Vec<_>>().join(","))),
        ("generation", JsonValue::Int(if min_gen == u64::MAX { 0 } else { min_gen as i64 })),
    ];
    ok_response(&fields)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partition_is_stable_balanced_and_order_independent() {
        let names = ["relu128", "mlp", "lenet", "attn_block_mh4", "convblock"];
        let a = partition_workloads(&names, 2);
        let mut reversed: Vec<&str> = names.to_vec();
        reversed.reverse();
        let b = partition_workloads(&reversed, 2);
        assert_eq!(a, b, "assignment must not depend on input order");
        assert_eq!(a.len(), 2);
        assert_eq!(a.iter().map(Vec::len).sum::<usize>(), names.len());
        assert!(a[0].len().abs_diff(a[1].len()) <= 1, "{a:?}");
        // Every workload lands on exactly one shard.
        let mut all: Vec<&String> = a.iter().flatten().collect();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), names.len());
    }

    #[test]
    fn partition_degenerate_widths() {
        let names = ["a", "b", "c"];
        let one = partition_workloads(&names, 1);
        assert_eq!(one.len(), 1);
        assert_eq!(one[0].len(), 3);
        // More shards than workloads: trailing shards stay empty (the
        // supervisor caps its shard count before calling this).
        let five = partition_workloads(&names, 5);
        assert_eq!(five.len(), 5);
        assert_eq!(five.iter().map(Vec::len).sum::<usize>(), 3);
        // Zero clamps to one.
        assert_eq!(partition_workloads(&names, 0).len(), 1);
    }

    #[test]
    fn partition_spreads_real_workload_names() {
        // The stable-hash order should not degenerate to one shard for
        // the actual registry (guards against a pathological hash).
        let wls = crate::relay::all_workloads();
        let names: Vec<&str> = wls.iter().map(|w| w.name.as_str()).collect();
        let groups = partition_workloads(&names, 2);
        assert!(!groups[0].is_empty() && !groups[1].is_empty(), "{groups:?}");
    }
}
