//! The serving wire protocol, as data: the command set and the error
//! taxonomy are **enums**, and every response string is rendered through
//! this module — so the protocol a running daemon speaks is exactly what
//! these types enumerate. The authoritative human-readable spec lives in
//! `docs/serving.md`; `rust/tests/serving.rs` cross-checks that document
//! against [`Command::ALL`] and [`ErrorCode::ALL`], so a command or error
//! variant cannot ship undocumented.
//!
//! Shape recap (one JSON object per line, both directions):
//!
//! * requests: `{"cmd":"<command>", ...command fields}`
//! * success: `{"ok":true, ...}`
//! * failure: `{"ok":false,"code":"<error code>","error":"<message>", ...}`

use crate::error::Error;
use crate::report::JsonValue;

/// Every command the daemon understands. `query` is the default when a
/// request omits `"cmd"` (so bare `{"workload":...}` lines work).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Command {
    /// Liveness probe; answers `{"ok":true,"pong":true}`.
    Ping,
    /// Answer a design-space query against one workload's session.
    Query,
    /// Serving counters: served/errors/rejected/timeouts, latency
    /// percentiles, queue depth, per-workload served counts.
    Stats,
    /// Hot snapshot reload: atomically re-load every resident workload's
    /// snapshot from disk without dropping in-flight connections.
    Reload,
    /// Acknowledge, then stop the accept loop and drain the worker pool.
    Shutdown,
}

impl Command {
    /// The full command set, in documentation order.
    pub const ALL: [Command; 5] =
        [Command::Ping, Command::Query, Command::Stats, Command::Reload, Command::Shutdown];

    /// The wire name (`"cmd"` field value).
    pub fn name(self) -> &'static str {
        match self {
            Command::Ping => "ping",
            Command::Query => "query",
            Command::Stats => "stats",
            Command::Reload => "reload",
            Command::Shutdown => "shutdown",
        }
    }

    /// Resolve a wire name; `None` for unknown commands (the caller turns
    /// that into a [`ErrorCode::BadRequest`] naming the valid set).
    pub fn parse(name: &str) -> Option<Command> {
        Command::ALL.into_iter().find(|c| c.name() == name)
    }

    /// The valid command names, for error messages.
    pub fn names() -> String {
        Command::ALL.map(Command::name).join(" | ")
    }
}

/// The error taxonomy: every `{"ok":false}` response carries exactly one
/// of these in its `"code"` field, so clients can branch on machine-
/// readable codes instead of matching message text.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorCode {
    /// Unparseable JSON, an unknown command, or invalid/missing request
    /// fields. Counted in the `errors` stat.
    BadRequest,
    /// The named workload is not registered with this daemon. Counted in
    /// the `errors` stat.
    UnknownWorkload,
    /// Typed backpressure: the bounded pending-connection queue (or the
    /// legacy path's connection cap) is full. Sent with a
    /// `retry_after_ms` hint; counted in the `rejected` stat.
    Busy,
    /// The request exceeded its `--request-timeout-ms` deadline. Sent
    /// with the configured `timeout_ms`; counted in the `timeouts` stat.
    Timeout,
    /// A snapshot on disk failed to decode (corrupt, truncated, or a
    /// format version this build cannot read) — surfaced by lazy loads
    /// and `reload`. Counted in the `errors` stat.
    SnapshotCorrupt,
    /// Any other failure (evaluation errors, unsupported backends, …).
    /// Counted in the `errors` stat.
    Internal,
}

impl ErrorCode {
    /// The full error taxonomy, in documentation order.
    pub const ALL: [ErrorCode; 6] = [
        ErrorCode::BadRequest,
        ErrorCode::UnknownWorkload,
        ErrorCode::Busy,
        ErrorCode::Timeout,
        ErrorCode::SnapshotCorrupt,
        ErrorCode::Internal,
    ];

    /// The wire name (`"code"` field value).
    pub fn name(self) -> &'static str {
        match self {
            ErrorCode::BadRequest => "bad_request",
            ErrorCode::UnknownWorkload => "unknown_workload",
            ErrorCode::Busy => "busy",
            ErrorCode::Timeout => "timeout",
            ErrorCode::SnapshotCorrupt => "snapshot_corrupt",
            ErrorCode::Internal => "internal",
        }
    }

    /// Map a crate error onto its wire code.
    pub fn classify(e: &Error) -> ErrorCode {
        match e {
            Error::UnknownWorkload(_) => ErrorCode::UnknownWorkload,
            Error::Busy { .. } => ErrorCode::Busy,
            Error::Timeout { .. } => ErrorCode::Timeout,
            Error::SnapshotCorrupt(_) | Error::SnapshotVersion { .. } => ErrorCode::SnapshotCorrupt,
            Error::Parse(_) | Error::InvalidConfig(_) => ErrorCode::BadRequest,
            _ => ErrorCode::Internal,
        }
    }
}

/// `{"ok":true, <fields...>}` through the report emitter's escaping.
pub fn ok_response(fields: &[(&str, JsonValue)]) -> String {
    let mut out = String::from("{\"ok\":true");
    push_fields(&mut out, fields);
    out.push('}');
    out
}

/// `{"ok":false,"code":...,"error":..., <extra fields...>}`. The extra
/// fields carry code-specific payloads (`retry_after_ms` for `busy`,
/// `timeout_ms` for `timeout`).
pub fn error_response(code: ErrorCode, msg: &str, extra: &[(&str, JsonValue)]) -> String {
    let mut out = format!(
        "{{\"ok\":false,\"code\":{},\"error\":{}",
        JsonValue::Str(code.name().to_string()).render(),
        JsonValue::Str(msg.to_string()).render()
    );
    push_fields(&mut out, extra);
    out.push('}');
    out
}

fn push_fields(out: &mut String, fields: &[(&str, JsonValue)]) {
    for (k, v) in fields {
        out.push(',');
        out.push_str(&JsonValue::Str(k.to_string()).render());
        out.push(':');
        out.push_str(&v.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::json::Json;

    #[test]
    fn command_names_round_trip_and_are_unique() {
        for cmd in Command::ALL {
            assert_eq!(Command::parse(cmd.name()), Some(cmd));
        }
        let mut names: Vec<_> = Command::ALL.map(Command::name).to_vec();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), Command::ALL.len());
        assert_eq!(Command::parse("frobnicate"), None);
        assert!(Command::names().contains("reload"));
    }

    #[test]
    fn error_codes_are_unique_and_classify_typed_errors() {
        let mut names: Vec<_> = ErrorCode::ALL.map(ErrorCode::name).to_vec();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), ErrorCode::ALL.len());

        let cases: [(Error, ErrorCode); 6] = [
            (Error::InvalidConfig("x".into()), ErrorCode::BadRequest),
            (Error::UnknownWorkload("x".into()), ErrorCode::UnknownWorkload),
            (Error::Busy { queued: 1, retry_after_ms: 10 }, ErrorCode::Busy),
            (Error::Timeout { phase: "extract" }, ErrorCode::Timeout),
            (Error::SnapshotCorrupt("bit flip".into()), ErrorCode::SnapshotCorrupt),
            (Error::Unsupported("pjrt".into()), ErrorCode::Internal),
        ];
        for (err, want) in cases {
            assert_eq!(ErrorCode::classify(&err), want, "{err}");
        }
    }

    #[test]
    fn error_response_is_valid_json_with_code_and_extras() {
        let resp = error_response(
            ErrorCode::Busy,
            "queue full",
            &[("retry_after_ms", JsonValue::Int(50))],
        );
        let j = Json::parse(&resp).expect("valid JSON");
        assert_eq!(j.get("ok").and_then(Json::as_bool), Some(false));
        assert_eq!(j.get("code").and_then(Json::as_str), Some("busy"));
        assert_eq!(j.get("error").and_then(Json::as_str), Some("queue full"));
        assert_eq!(j.get("retry_after_ms").and_then(Json::as_u64), Some(50));
    }
}
