//! A minimal JSON reader for the serving protocol (no serde in the
//! zero-dependency build). Writes stay hand-rolled through
//! [`crate::report::JsonValue`]; this is the *read* half — requests are
//! tiny flat objects, so the parser favors clarity over speed.

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Key/value pairs in source order (duplicate keys: first wins on
    /// [`Json::get`]).
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Parse one JSON document; trailing non-whitespace is an error.
    pub fn parse(src: &str) -> Result<Json, String> {
        let mut p = Parser { bytes: src.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing characters at byte {}", p.pos));
        }
        Ok(v)
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    /// Numeric field as an unsigned integer (rejects negatives/fractions).
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(v) if *v >= 0.0 && v.fract() == 0.0 && *v <= u64::MAX as f64 => {
                Some(*v as u64)
            }
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", b as char, self.pos))
        }
    }

    fn literal(&mut self, word: &str, value: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            Err(format!("invalid literal at byte {}", self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(b) if b == b'-' || b.is_ascii_digit() => self.number(),
            Some(b) => Err(format!("unexpected '{}' at byte {}", b as char, self.pos)),
            None => Err("unexpected end of input".to_string()),
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        while let Some(b) = self.peek() {
            if b.is_ascii_digit() || matches!(b, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).expect("ascii");
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| format!("invalid number '{text}' at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".to_string()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{0008}'),
                        Some(b'f') => out.push('\u{000C}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or("truncated \\u escape")?;
                            let code = std::str::from_utf8(hex)
                                .ok()
                                .and_then(|h| u32::from_str_radix(h, 16).ok())
                                .ok_or("invalid \\u escape")?;
                            // Lone surrogates render as the replacement
                            // char; the protocol never emits them.
                            out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        _ => return Err(format!("invalid escape at byte {}", self.pos)),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar (multi-byte safe: find the
                    // char at this byte offset in the source).
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| "invalid UTF-8 in string".to_string())?;
                    let c = rest.chars().next().expect("non-empty");
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.pos)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let value = self.value()?;
            fields.push((key, value));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(fields));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.pos)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_flat_request_objects() {
        let j = Json::parse(r#"{"cmd":"query","workload":"relu128","samples":16,"seed":3}"#)
            .unwrap();
        assert_eq!(j.get("cmd").and_then(Json::as_str), Some("query"));
        assert_eq!(j.get("samples").and_then(Json::as_u64), Some(16));
        assert_eq!(j.get("seed").and_then(Json::as_u64), Some(3));
        assert!(j.get("missing").is_none());
    }

    #[test]
    fn parses_nested_values_and_escapes() {
        let j = Json::parse(
            r#"{"a":[1, -2.5, true, false, null], "s":"q\"\\\nA", "o":{"k":2}}"#,
        )
        .unwrap();
        let arr = j.get("a").and_then(Json::as_array).unwrap();
        assert_eq!(arr.len(), 5);
        assert_eq!(arr[1].as_f64(), Some(-2.5));
        assert_eq!(arr[2].as_bool(), Some(true));
        assert_eq!(arr[4], Json::Null);
        assert_eq!(j.get("s").and_then(Json::as_str), Some("q\"\\\nA"));
        assert_eq!(j.get("o").and_then(|o| o.get("k")).and_then(Json::as_u64), Some(2));
    }

    #[test]
    fn round_trips_report_emitter_output() {
        // The bench merge path parses what report::JsonRecords writes.
        let mut recs = crate::report::JsonRecords::new();
        recs.push(vec![
            ("workload".into(), crate::report::JsonValue::Str("le\"net".into())),
            ("wall_ms".into(), crate::report::JsonValue::Num(12.5)),
            ("n".into(), crate::report::JsonValue::Int(3)),
        ]);
        let parsed = Json::parse(&recs.to_json()).unwrap();
        let arr = parsed.as_array().unwrap();
        assert_eq!(arr.len(), 1);
        assert_eq!(arr[0].get("workload").and_then(Json::as_str), Some("le\"net"));
        assert_eq!(arr[0].get("wall_ms").and_then(Json::as_f64), Some(12.5));
        assert_eq!(arr[0].get("n").and_then(Json::as_u64), Some(3));
    }

    #[test]
    fn rejects_malformed_input() {
        for bad in ["", "{", "{\"a\"}", "[1,]", "tru", "\"unterminated", "{} extra", "nan"] {
            assert!(Json::parse(bad).is_err(), "should reject: {bad}");
        }
    }

    #[test]
    fn as_u64_rejects_negatives_and_fractions() {
        assert_eq!(Json::parse("-3").unwrap().as_u64(), None);
        assert_eq!(Json::parse("2.5").unwrap().as_u64(), None);
        assert_eq!(Json::parse("7").unwrap().as_u64(), Some(7));
    }
}
