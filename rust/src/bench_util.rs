//! A tiny benchmarking harness for the `harness = false` bench binaries
//! (criterion is not in the vendored dependency set). Provides warmup,
//! repeated timed runs, and median/mean/min reporting, plus a `black_box`
//! to defeat constant folding.

use std::hint::black_box as std_black_box;
use std::time::{Duration, Instant};

/// Re-export of `std::hint::black_box` under the criterion-familiar name.
pub fn black_box<T>(x: T) -> T {
    std_black_box(x)
}

/// Timing summary of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub runs: usize,
    pub mean: Duration,
    pub median: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl BenchResult {
    pub fn line(&self) -> String {
        format!(
            "{:<40} runs={:<3} min={:>12.3?} median={:>12.3?} mean={:>12.3?}",
            self.name, self.runs, self.min, self.median, self.mean
        )
    }

    /// Median in nanoseconds (for CSV output).
    pub fn median_ns(&self) -> u128 {
        self.median.as_nanos()
    }
}

/// Run `f` with warmup then `runs` timed iterations.
pub fn bench(name: &str, warmup: usize, runs: usize, mut f: impl FnMut()) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut times: Vec<Duration> = Vec::with_capacity(runs);
    for _ in 0..runs {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed());
    }
    times.sort();
    let mean = times.iter().sum::<Duration>() / runs.max(1) as u32;
    let result = BenchResult {
        name: name.to_string(),
        runs,
        mean,
        median: times[times.len() / 2],
        min: times[0],
        max: *times.last().unwrap(),
    };
    println!("{}", result.line());
    result
}

/// Auto-calibrating variant: picks an iteration count so the whole
/// measurement takes roughly `target` wall-clock.
pub fn bench_auto(name: &str, target: Duration, mut f: impl FnMut()) -> BenchResult {
    // Calibrate.
    let t0 = Instant::now();
    f();
    let one = t0.elapsed().max(Duration::from_nanos(100));
    let runs = (target.as_nanos() / one.as_nanos()).clamp(3, 1000) as usize;
    bench(name, runs.min(3), runs, f)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_stats() {
        let r = bench("noop", 1, 11, || {
            black_box(1 + 1);
        });
        assert_eq!(r.runs, 11);
        assert!(r.min <= r.median && r.median <= r.max);
    }

    #[test]
    fn bench_auto_clamps_runs() {
        let r = bench_auto("sleepless", Duration::from_millis(5), || {
            black_box((0..1000).sum::<u64>());
        });
        assert!(r.runs >= 3);
    }
}
