//! A tiny benchmarking harness for the `harness = false` bench binaries
//! (criterion is not in the vendored dependency set). Provides warmup,
//! repeated timed runs, and median/mean/min reporting, plus a `black_box`
//! to defeat constant folding — and the shared snapshot-fixture helpers
//! the `perf_quick` and `serving` benches both build on.

use crate::egraph::RunnerLimits;
use crate::relay::workload_by_name;
use crate::rewrites::RuleSet;
use crate::session::Session;
use std::hint::black_box as std_black_box;
use std::path::PathBuf;
use std::time::{Duration, Instant};

/// Re-export of `std::hint::black_box` under the criterion-familiar name.
pub fn black_box<T>(x: T) -> T {
    std_black_box(x)
}

/// Timing summary of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub runs: usize,
    pub mean: Duration,
    pub median: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl BenchResult {
    pub fn line(&self) -> String {
        format!(
            "{:<40} runs={:<3} min={:>12.3?} median={:>12.3?} mean={:>12.3?}",
            self.name, self.runs, self.min, self.median, self.mean
        )
    }

    /// Median in nanoseconds (for CSV output).
    pub fn median_ns(&self) -> u128 {
        self.median.as_nanos()
    }
}

/// Run `f` with warmup then `runs` timed iterations.
pub fn bench(name: &str, warmup: usize, runs: usize, mut f: impl FnMut()) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut times: Vec<Duration> = Vec::with_capacity(runs);
    for _ in 0..runs {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed());
    }
    times.sort();
    let mean = times.iter().sum::<Duration>() / runs.max(1) as u32;
    let result = BenchResult {
        name: name.to_string(),
        runs,
        mean,
        median: times[times.len() / 2],
        min: times[0],
        max: *times.last().unwrap(),
    };
    println!("{}", result.line());
    result
}

/// Auto-calibrating variant: picks an iteration count so the whole
/// measurement takes roughly `target` wall-clock.
pub fn bench_auto(name: &str, target: Duration, mut f: impl FnMut()) -> BenchResult {
    // Calibrate.
    let t0 = Instant::now();
    f();
    let one = t0.elapsed().max(Duration::from_nanos(100));
    let runs = (target.as_nanos() / one.as_nanos()).clamp(3, 1000) as usize;
    bench(name, runs.min(3), runs, f)
}

/// Where the shared snapshot fixtures live. The filename is tagged with
/// the enumeration budget — so a changed bench budget never silently
/// reuses a stale fixture from an earlier run — and with the persist
/// [`FORMAT_VERSION`](crate::persist::FORMAT_VERSION), so a format bump
/// re-saturates rather than serving benches from a fixture that exercises
/// the old codec's back-compat path instead of the current encoder.
pub fn snapshot_fixture_path(
    workload: &str,
    rules: RuleSet,
    iters: usize,
    max_nodes: usize,
) -> PathBuf {
    let set = match rules {
        RuleSet::Fig2 => "fig2",
        RuleSet::Paper => "paper",
        RuleSet::All => "all",
    };
    let version = crate::persist::FORMAT_VERSION;
    PathBuf::from("target/snapshots")
        .join(format!("{workload}-{set}-i{iters}-n{max_nodes}-v{version}.hws"))
}

/// Return a session for `workload` backed by the on-disk snapshot fixture,
/// saturating and saving it on first use. Both bench binaries go through
/// this helper so they measure against the identical saturated graph; a
/// loaded fixture answers queries with zero re-saturation.
pub fn snapshot_fixture(
    workload: &str,
    rules: RuleSet,
    iters: usize,
    max_nodes: usize,
) -> Session {
    let path = snapshot_fixture_path(workload, rules, iters, max_nodes);
    if let Ok(session) = Session::load_snapshot(&path) {
        return session;
    }
    let w = workload_by_name(workload)
        .unwrap_or_else(|| panic!("unknown workload '{workload}'"));
    let mut session = Session::builder()
        .workload(w)
        .rules(rules)
        .iters(iters)
        .limits(RunnerLimits { max_nodes, track_designs: false, ..Default::default() })
        .build()
        .expect("fixture session builds");
    session.save_snapshot(&path).expect("fixture snapshot writes");
    session
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_stats() {
        let r = bench("noop", 1, 11, || {
            black_box(1 + 1);
        });
        assert_eq!(r.runs, 11);
        assert!(r.min <= r.median && r.median <= r.max);
    }

    #[test]
    fn snapshot_fixture_builds_then_loads() {
        // A budget no bench uses, so this test owns the file.
        let p = snapshot_fixture_path("relu128", RuleSet::Fig2, 3, 3_000);
        let _ = std::fs::remove_file(&p);
        let s1 = snapshot_fixture("relu128", RuleSet::Fig2, 3, 3_000);
        assert!(p.exists(), "first call must write the fixture");
        assert_eq!(s1.enumeration_count(), 1);
        let s2 = snapshot_fixture("relu128", RuleSet::Fig2, 3, 3_000);
        assert_eq!(s2.enumeration_count(), 0, "second call must load, not re-saturate");
        assert!(s2.enumeration().is_some(), "loaded fixture is ready to serve");
    }

    #[test]
    fn fixture_path_is_versioned_by_snapshot_format() {
        let p = snapshot_fixture_path("relu128", RuleSet::Fig2, 3, 3_000);
        let name = p.file_name().unwrap().to_string_lossy().into_owned();
        assert!(
            name.contains(&format!("-v{}", crate::persist::FORMAT_VERSION)),
            "fixture name must carry the persist format version: {name}"
        );
    }

    #[test]
    fn bench_auto_clamps_runs() {
        let r = bench_auto("sleepless", Duration::from_millis(5), || {
            black_box((0..1000).sum::<u64>());
        });
        assert!(r.runs >= 3);
    }
}
