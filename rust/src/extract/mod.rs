//! Extraction: picking concrete designs back out of the e-graph — as a
//! **parallel, memoized, streaming** serving layer.
//!
//! The paper explicitly scopes extraction out ("the extraction procedure is
//! out of the scope of this early work") — but the evaluation methodology
//! (§3 diversity + usefulness) needs *many* concrete design points, and the
//! ROADMAP's serving goal needs them fast and repeatedly. The read side is
//! therefore built around three ideas:
//!
//! 1. **Cost-table memoization.** The expensive part of one extraction is
//!    the bottom-up cost fixpoint, and it depends only on the e-graph and
//!    the cost function — not on the query. [`CostTable`] is that fixpoint
//!    solution as a reusable snapshot, and [`ExtractCache`] memoizes tables
//!    keyed on ([`CostKind`], graph epoch): shared read-only across
//!    queries, invalidated only when the e-graph actually changes
//!    ([`EGraph::epoch`]). A repeated query pays zero fixpoint rebuilds —
//!    and when the graph *has* changed, a stale table is not discarded but
//!    **incrementally re-solved** ([`CostTable::build_incremental`]): the
//!    previous fixpoint seeds the worklist and only the dirty ancestor
//!    closure (from [`EGraph::changed_since`]) is re-relaxed, reaching the
//!    same least fixpoint a scratch build would (asserted in debug builds;
//!    `HWSPLIT_COST_INCR=0` opts out).
//! 2. **Parallel sampling.** [`extract_designs`] fans the seeded sample
//!    extractions out over the shared worker pool
//!    ([`crate::par::parallel_map`]), one independent seeded-RNG extraction
//!    per item; order-preserving merge makes the result bit-identical for
//!    any worker count (mirroring the saturation engine's search shards).
//! 3. **Streaming Pareto frontier.** [`ParetoFrontier`] maintains the
//!    area/latency frontier incrementally — insert with dominated-point
//!    eviction, `O(n·|frontier|)` — instead of collecting every sample and
//!    filtering all-vs-all (`O(n²)`). [`pareto_frontier`] remains as the
//!    collect-then-filter reference the equivalence tests compare against.
//!
//! Entry points: [`Extractor`] (one-off greedy extraction with a pluggable
//! per-node cost), [`sample_design`] / [`sample_designs`] (seeded diverse
//! sampling), [`extract_designs`] (the full parallel+memoized pass with
//! [`ExtractedSet`] memo accounting — what [`crate::session`] queries run),
//! and [`ParetoExplorer`] (samples + greedy endpoints reduced to the
//! frontier, streamed).

use crate::cost::{analyze, CostParams, DesignCost, DesignStats};
use crate::egraph::{EGraph, Id};
use crate::fx::FxHashMap as HashMap;
use crate::ir::{Node, Op, RecExpr};
use crate::par::{default_workers, parallel_map};
use crate::prop::Rng;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// A per-node extraction cost: receives the candidate e-node and the cost
/// of each child *class* (already minimized); returns the node's total.
pub type NodeCost<'a> = dyn Fn(&EGraph, &Node, &dyn Fn(Id) -> f64) -> f64 + 'a;

/// The solved bottom-up cost fixpoint for one cost function over one
/// e-graph: per class, the cheapest e-node and its cost. Self-contained
/// (no borrow of the cost function), so it can be memoized in an
/// [`ExtractCache`] and shared read-only across queries and worker threads.
#[derive(Debug, Clone)]
pub struct CostTable {
    /// class -> (best cost, best node)
    best: HashMap<Id, (f64, Node)>,
}

impl CostTable {
    /// Solve the fixpoint for `cost_fn` against `eg`.
    ///
    /// Worklist fixpoint: when a class's best cost improves, only the
    /// e-nodes that reference it are re-evaluated (near-linear in
    /// practice; the naive repeat-all-passes version is quadratic and
    /// dominates exploration time on large e-graphs).
    pub fn build(
        eg: &EGraph,
        cost_fn: impl Fn(&EGraph, &Node, &dyn Fn(Id) -> f64) -> f64,
    ) -> Self {
        let (nodes, parents) = snapshot(eg);
        let queue: std::collections::VecDeque<usize> = (0..nodes.len()).collect();
        let best = relax(eg, &cost_fn, HashMap::default(), &nodes, &parents, queue);
        CostTable { best }
    }

    /// Re-solve the fixpoint after an e-graph mutation, seeded from the
    /// previous solution. Every previous entry is the cost of a term that
    /// still exists (nodes are never removed, classes only merge), so the
    /// find-remapped, min-merged seed is a valid upper bound per class and
    /// relaxation only moves costs *down* — to the same least fixpoint a
    /// from-scratch build reaches ([`costs_agree`] pins this, and the
    /// cache's debug builds assert it on every incremental reuse).
    ///
    /// Only the dirty frontier is re-queued: e-nodes *in* a changed class
    /// (new or merged alternatives) and e-nodes *referencing* one (a merge
    /// may have lowered the child's min). Improvements propagate to
    /// transitive ancestors through the ordinary worklist relaxation.
    pub fn build_incremental(
        eg: &EGraph,
        cost_fn: impl Fn(&EGraph, &Node, &dyn Fn(Id) -> f64) -> f64,
        prev: &CostTable,
        dirty: &[Id],
    ) -> Self {
        let mut best: HashMap<Id, (f64, Node)> = HashMap::default();
        for (&id, entry) in prev.best.iter() {
            let id = eg.find_ref(id);
            match best.entry(id) {
                std::collections::hash_map::Entry::Vacant(v) => {
                    v.insert(entry.clone());
                }
                std::collections::hash_map::Entry::Occupied(mut o) => {
                    if entry.0 < o.get().0 {
                        o.insert(entry.clone());
                    }
                }
            }
        }
        let (nodes, parents) = snapshot(eg);
        let mut by_class: HashMap<Id, Vec<usize>> = HashMap::default();
        for (i, (cid, _)) in nodes.iter().enumerate() {
            by_class.entry(*cid).or_default().push(i);
        }
        let mut queue = std::collections::VecDeque::new();
        let mut seeded = vec![false; nodes.len()];
        for d in dirty {
            let d = eg.find_ref(*d);
            for idx in [by_class.get(&d), parents.get(&d)].into_iter().flatten() {
                for &i in idx {
                    if !seeded[i] {
                        seeded[i] = true;
                        queue.push_back(i);
                    }
                }
            }
        }
        let best = relax(eg, &cost_fn, best, &nodes, &parents, queue);
        CostTable { best }
    }

    /// Solve the fixpoint for a named [`CostKind`].
    pub fn build_kind(eg: &EGraph, kind: &CostKind) -> Self {
        match kind {
            CostKind::Size => CostTable::build(eg, size_cost),
            CostKind::Latency => CostTable::build(eg, latency_cost),
            CostKind::Area => CostTable::build(eg, area_cost),
            CostKind::Sampled(seed) => CostTable::build(eg, sampled_cost(*seed)),
        }
    }

    /// [`CostTable::build_incremental`] for a named [`CostKind`].
    pub fn build_kind_incremental(
        eg: &EGraph,
        kind: &CostKind,
        prev: &CostTable,
        dirty: &[Id],
    ) -> Self {
        match kind {
            CostKind::Size => CostTable::build_incremental(eg, size_cost, prev, dirty),
            CostKind::Latency => CostTable::build_incremental(eg, latency_cost, prev, dirty),
            CostKind::Area => CostTable::build_incremental(eg, area_cost, prev, dirty),
            CostKind::Sampled(seed) => {
                CostTable::build_incremental(eg, sampled_cost(*seed), prev, dirty)
            }
        }
    }

    /// Best cost of a class, if extractable.
    pub fn cost(&self, eg: &EGraph, id: Id) -> Option<f64> {
        self.best.get(&eg.find_ref(id)).map(|(c, _)| *c)
    }

    /// The solved `class -> (cost, node)` map, for the snapshot codec.
    pub(crate) fn raw_entries(&self) -> &HashMap<Id, (f64, Node)> {
        &self.best
    }

    /// Rebuild from a decoded entry map (snapshot load).
    pub(crate) fn from_raw(best: HashMap<Id, (f64, Node)>) -> Self {
        CostTable { best }
    }

    /// Extract the best design rooted at `root`.
    pub fn extract(&self, eg: &EGraph, root: Id) -> RecExpr {
        let mut expr = RecExpr::new();
        let mut memo: HashMap<Id, Id> = HashMap::default();
        let id = self.extract_rec(eg, eg.find_ref(root), &mut expr, &mut memo);
        debug_assert_eq!(id, expr.root());
        expr
    }

    fn extract_rec(
        &self,
        eg: &EGraph,
        id: Id,
        expr: &mut RecExpr,
        memo: &mut HashMap<Id, Id>,
    ) -> Id {
        let id = eg.find_ref(id);
        if let Some(&done) = memo.get(&id) {
            return done;
        }
        let (_, node) = self.best.get(&id).expect("extract: class has no finite cost");
        let children: Vec<Id> = node
            .children
            .iter()
            .map(|&c| self.extract_rec(eg, c, expr, memo))
            .collect();
        let new_id = expr.add(Node::new(node.op.clone(), children));
        memo.insert(id, new_id);
        new_id
    }
}

/// Snapshot every e-node with its class, plus a child -> referencing-nodes
/// index (both shared by the scratch and incremental fixpoint builds).
fn snapshot(eg: &EGraph) -> (Vec<(Id, Node)>, HashMap<Id, Vec<usize>>) {
    let mut nodes: Vec<(Id, Node)> = Vec::new();
    for class in eg.classes() {
        for node in eg.class_nodes(class.id) {
            nodes.push((class.id, node.clone()));
        }
    }
    let mut parents: HashMap<Id, Vec<usize>> = HashMap::default();
    for (i, (_, node)) in nodes.iter().enumerate() {
        for &c in &node.children {
            parents.entry(eg.find_ref(c)).or_default().push(i);
        }
    }
    (nodes, parents)
}

/// Worklist relaxation to the least cost fixpoint: drain the queue,
/// re-queueing the parents of any class whose best improves. `best` may be
/// pre-seeded with upper bounds (the incremental path); relaxation only
/// ever lowers entries.
fn relax(
    eg: &EGraph,
    cost_fn: &impl Fn(&EGraph, &Node, &dyn Fn(Id) -> f64) -> f64,
    mut best: HashMap<Id, (f64, Node)>,
    nodes: &[(Id, Node)],
    parents: &HashMap<Id, Vec<usize>>,
    mut queue: std::collections::VecDeque<usize>,
) -> HashMap<Id, (f64, Node)> {
    let mut queued: Vec<bool> = vec![false; nodes.len()];
    for &i in &queue {
        queued[i] = true;
    }
    while let Some(i) = queue.pop_front() {
        queued[i] = false;
        let (cid, node) = &nodes[i];
        let ready = node.children.iter().all(|&c| best.contains_key(&eg.find_ref(c)));
        if !ready {
            continue;
        }
        let lookup = |id: Id| best[&eg.find_ref(id)].0;
        let cost = cost_fn(eg, node, &lookup);
        let improves = best.get(cid).map_or(true, |(old, _)| cost < *old);
        if improves {
            best.insert(*cid, (cost, node.clone()));
            if let Some(ps) = parents.get(cid) {
                for &p in ps {
                    if !queued[p] {
                        queued[p] = true;
                        queue.push_back(p);
                    }
                }
            }
        }
    }
    best
}

/// Do two solved tables assign the same cost to every class? The winning
/// *node* may differ (tie-breaking depends on relaxation order); the cost
/// fixpoint itself is unique, and this is the equivalence the incremental
/// build is held to — bit-exact, since per-node cost arithmetic is
/// deterministic given equal child costs.
pub fn costs_agree(a: &CostTable, b: &CostTable, eg: &EGraph) -> bool {
    let canon = |t: &CostTable| -> HashMap<Id, f64> {
        t.best.iter().map(|(&id, (c, _))| (eg.find_ref(id), *c)).collect()
    };
    let (ca, cb) = (canon(a), canon(b));
    ca.len() == cb.len()
        && ca.iter().all(|(id, c)| cb.get(id).is_some_and(|d| c.to_bits() == d.to_bits()))
}

/// Incremental cost-table reuse is on unless `HWSPLIT_COST_INCR=0` — the
/// escape hatch the perf CI uses to benchmark scratch vs incremental.
fn incremental_enabled() -> bool {
    static ON: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
    *ON.get_or_init(|| std::env::var("HWSPLIT_COST_INCR").map_or(true, |v| v != "0"))
}

/// Bottom-up fixpoint extractor over an arbitrary (possibly closure-
/// captured) cost function — the one-off convenience front over
/// [`CostTable`]. Memoizable named costs go through [`ExtractCache`]
/// instead.
pub struct Extractor {
    table: CostTable,
}

impl Extractor {
    /// Run the fixpoint against `eg` with `cost_fn`.
    pub fn new(
        eg: &EGraph,
        cost_fn: impl Fn(&EGraph, &Node, &dyn Fn(Id) -> f64) -> f64,
    ) -> Self {
        Extractor { table: CostTable::build(eg, cost_fn) }
    }

    /// Best cost of a class, if extractable.
    pub fn cost(&self, eg: &EGraph, id: Id) -> Option<f64> {
        self.table.cost(eg, id)
    }

    /// Extract the best design rooted at `root`.
    pub fn extract(&self, eg: &EGraph, root: Id) -> RecExpr {
        self.table.extract(eg, root)
    }

    /// Surrender the solved fixpoint for caching.
    pub fn into_table(self) -> CostTable {
        self.table
    }
}

/// Identity of a memoizable extraction cost function — one half of the
/// [`ExtractCache`] key (the other half is the graph epoch).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum CostKind {
    /// [`latency_cost`] (the greedy-latency endpoint).
    Latency,
    /// [`area_cost`] (the greedy-area endpoint).
    Area,
    /// [`size_cost`] (smallest term).
    Size,
    /// [`latency_cost`] under seeded multiplicative noise — one diverse
    /// sample per seed (see [`sample_design`]).
    Sampled(u64),
}

/// Cap on memoized [`CostKind::Sampled`] tables per cache. Named kinds
/// (greedy endpoints) are never evicted; sampled tables are FIFO-evicted
/// past this bound so a long-lived session cycling through seeds can't
/// grow one per-class table per seed forever. Large enough that every
/// realistic per-query sample count (default 64) stays fully memoized.
const MAX_SAMPLED_TABLES: usize = 256;

#[derive(Debug, Default)]
struct CacheInner {
    /// Per-kind solved tables, each tagged with the [`EGraph::epoch`] it
    /// was solved against. A stale entry is not discarded on epoch bump:
    /// it is the *seed* for the next incremental re-solve.
    tables: HashMap<CostKind, (u64, Arc<CostTable>)>,
    /// Insertion order of the `Sampled` keys currently in `tables`, for
    /// FIFO eviction at [`MAX_SAMPLED_TABLES`].
    sampled_order: std::collections::VecDeque<CostKind>,
}

/// Memo of solved [`CostTable`]s, keyed on (cost-fn identity, graph
/// epoch): tables are shared read-only across queries and across the
/// extraction worker pool, and the whole cache self-invalidates the first
/// time it is consulted after the e-graph changed. One cache serves one
/// e-graph — the epoch detects *mutation*, not graph identity, so do not
/// share a cache between graphs (sessions own one per enumeration).
#[derive(Debug, Default)]
pub struct ExtractCache {
    inner: Mutex<CacheInner>,
}

impl ExtractCache {
    pub fn new() -> Self {
        ExtractCache::default()
    }

    /// Fetch the solved table for `kind`, building it on a miss. Returns
    /// the table and whether it was a memo hit. Callable concurrently from
    /// extraction workers: the fixpoint itself runs outside the lock (each
    /// sample seed is a distinct kind, so concurrent builds don't contend),
    /// and a racing duplicate build resolves first-insert-wins — harmless,
    /// since builds are deterministic.
    pub fn table(&self, eg: &EGraph, kind: CostKind) -> (Arc<CostTable>, bool) {
        let epoch = eg.epoch();
        // A stale entry isn't a plain miss: it seeds an incremental
        // re-solve over just the dirty ancestor closure (when the graph's
        // dirty log still covers the entry's epoch).
        let prev = {
            let inner = self.inner.lock().unwrap();
            match inner.tables.get(&kind) {
                Some((e, t)) if *e == epoch => return (t.clone(), true),
                Some((e, t)) => Some((*e, t.clone())),
                None => None,
            }
        };
        let built = Arc::new(match prev {
            Some((since, old)) if incremental_enabled() => {
                match eg.changed_since(since) {
                    Some(dirty) => {
                        let t = CostTable::build_kind_incremental(eg, &kind, &old, &dirty);
                        debug_assert!(
                            costs_agree(&t, &CostTable::build_kind(eg, &kind), eg),
                            "incremental cost table diverged from scratch ({kind:?})"
                        );
                        t
                    }
                    None => CostTable::build_kind(eg, &kind),
                }
            }
            _ => CostTable::build_kind(eg, &kind),
        });
        let mut inner = self.inner.lock().unwrap();
        if let Some((e, t)) = inner.tables.get(&kind) {
            if *e == epoch {
                // A racing build won; builds are deterministic, keep it.
                return (t.clone(), false);
            }
        }
        let newly = !inner.tables.contains_key(&kind);
        inner.tables.insert(kind.clone(), (epoch, built.clone()));
        if newly && matches!(kind, CostKind::Sampled(_)) {
            inner.sampled_order.push_back(kind);
            if inner.sampled_order.len() > MAX_SAMPLED_TABLES {
                if let Some(evict) = inner.sampled_order.pop_front() {
                    inner.tables.remove(&evict);
                }
            }
        }
        (built, false)
    }

    /// Number of cached tables (for tests / stats).
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().tables.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Export the cache contents for the snapshot codec: the epoch the
    /// tables were solved against, every table in a deterministic order
    /// (named kinds first, then sampled by seed — `HashMap` iteration order
    /// must not leak into snapshot bytes), and the sampled-key FIFO order.
    pub(crate) fn export(&self) -> CacheExport {
        let inner = self.inner.lock().unwrap();
        let mut tables: Vec<(CostKind, u64, Arc<CostTable>)> =
            inner.tables.iter().map(|(k, (e, t))| (k.clone(), *e, t.clone())).collect();
        tables.sort_by_key(|(k, _, _)| kind_rank(k));
        CacheExport { tables, sampled_order: inner.sampled_order.iter().cloned().collect() }
    }

    /// Rebuild a cache from exported contents (snapshot load). Tables stay
    /// valid as long as the loaded graph reports the epoch each entry was
    /// solved against — which [`crate::egraph`]'s raw-parts round trip
    /// guarantees for up-to-date entries.
    pub(crate) fn import(export: CacheExport) -> Self {
        ExtractCache {
            inner: Mutex::new(CacheInner {
                tables: export
                    .tables
                    .into_iter()
                    .map(|(k, e, t)| (k, (e, t)))
                    .collect(),
                sampled_order: export.sampled_order.into_iter().collect(),
            }),
        }
    }
}

/// Deterministic ordering key for [`CostKind`]s in exports.
fn kind_rank(k: &CostKind) -> (u8, u64) {
    match k {
        CostKind::Latency => (0, 0),
        CostKind::Area => (1, 0),
        CostKind::Size => (2, 0),
        CostKind::Sampled(seed) => (3, *seed),
    }
}

/// Owned [`ExtractCache`] contents, the unit the snapshot codec persists.
/// Each table carries the [`EGraph::epoch`] it was solved against.
#[derive(Debug)]
pub(crate) struct CacheExport {
    pub tables: Vec<(CostKind, u64, Arc<CostTable>)>,
    pub sampled_order: Vec<CostKind>,
}

/// Node-count cost (smallest term).
pub fn size_cost(_eg: &EGraph, node: &Node, child: &dyn Fn(Id) -> f64) -> f64 {
    1.0 + node.children.iter().map(|&c| child(c)).sum::<f64>()
}

/// A local approximation of the latency model in [`crate::cost`]: enough to
/// steer greedy extraction toward fast designs (the exact model runs on the
/// extracted tree afterwards).
pub fn latency_cost(eg: &EGraph, node: &Node, child: &dyn Fn(Id) -> f64) -> f64 {
    let p = CostParams::default();
    let kids: f64 = node.children.iter().map(|&c| child(c)).sum();
    let out_elems = |id: Id| -> f64 {
        eg.ty(id).shape().map(|s| s.numel() as f64).unwrap_or(0.0)
    };
    match &node.op {
        op if op.is_invoke() => {
            let mut io = 0.0;
            for &a in &node.children[1..] {
                io += out_elems(a);
            }
            kids + p.startup + io / p.port_width
        }
        Op::SchedLoop { extent, .. } => *extent as f64 * (kids + p.loop_overhead),
        Op::SchedPar { extent, .. } => kids + (*extent as f64).log2().ceil() * p.loop_overhead,
        Op::SchedReduce { extent, .. } => *extent as f64 * (kids + p.loop_overhead),
        Op::Buffer { .. } | Op::DblBuffer { .. } => kids + 1.0,
        // Materializing layout transforms (pad2d/im2col/transpose/…).
        op if matches!(op.class(), crate::ir::OpClass::Data) && op.spec().data_traffic => {
            kids + 4.0
        }
        op if op.is_relay() => kids + 1e7, // host fallback: avoid at all costs
        _ => kids,
    }
}

/// Area-leaning cost: engine MACs dominate (steers toward small shared
/// engines and deep loops).
pub fn area_cost(_eg: &EGraph, node: &Node, child: &dyn Fn(Id) -> f64) -> f64 {
    let kids: f64 = node.children.iter().map(|&c| child(c)).sum();
    match &node.op {
        op if op.is_engine() => op.engine_macs() as f64,
        // NOTE: tree-cost approximation double-counts shared engines; the
        // exact DAG-aware area is computed on the extracted tree.
        Op::SchedPar { extent, .. } => kids * *extent as f64,
        op if op.is_relay() => kids + 1e7,
        _ => kids + 0.001, // slight size pressure
    }
}

/// Process-stable structural hash of an e-node: registry name + attribute
/// values + children ids through the in-tree deterministic [`FxHasher`].
/// Symbols hash by their *string*, not their intern id — intern ids depend
/// on interning order, which differs between processes, and the sampled
/// extraction noise below must be bit-identical across a snapshot
/// save/load boundary.
fn stable_node_hash(seed: u64, node: &Node) -> u64 {
    use crate::fx::FxHasher;
    use crate::ir::spec::AttrVal;
    use std::hash::Hasher;
    let mut h = FxHasher::default();
    h.write_u64(seed);
    let spec = node.op.spec();
    h.write(spec.name.as_bytes());
    for attr in (spec.attrs_of)(&node.op) {
        match attr {
            AttrVal::U(v) => {
                h.write_u8(0);
                h.write_u64(v as u64);
            }
            AttrVal::I(v) => {
                h.write_u8(1);
                h.write_u64(v as u64);
            }
            AttrVal::Sym(s) => {
                h.write_u8(2);
                h.write(s.as_str().as_bytes());
            }
            AttrVal::Sh(s) => {
                h.write_u8(3);
                h.write_u64(s.0.len() as u64);
                for &d in &s.0 {
                    h.write_u64(d as u64);
                }
            }
            AttrVal::Buf(b) => {
                h.write_u8(4);
                h.write(b.as_str().as_bytes());
            }
        }
    }
    for &c in &node.children {
        h.write_u32(c.index() as u32);
    }
    h.finish()
}

/// [`latency_cost`] under per-node deterministic multiplicative noise —
/// the cost function behind [`CostKind::Sampled`]: each seed flips enough
/// local decisions to yield a distinct valid design. The noise hashes the
/// node *structurally* ([`stable_node_hash`]), so a given (graph, seed)
/// pair extracts the same design in every process — the property the
/// snapshot round-trip tests pin.
fn sampled_cost(seed: u64) -> impl Fn(&EGraph, &Node, &dyn Fn(Id) -> f64) -> f64 {
    move |eg, node, child| {
        let mut r = Rng::new(stable_node_hash(seed, node) | 1);
        // Noise in [0.25, 4.0) — enough to flip most local decisions.
        let noise = 0.25 * (1.0 + 15.0 * r.f64());
        latency_cost(eg, node, child) * noise + 1.0
    }
}

/// One extracted design point with its evaluation.
#[derive(Debug, Clone)]
pub struct DesignPoint {
    pub expr: RecExpr,
    pub cost: DesignCost,
    pub stats: DesignStats,
    /// How this point was produced (greedy-latency / greedy-area / sample-i).
    pub origin: String,
}

/// Randomized-cost extraction: seeded multiplicative noise on
/// [`latency_cost`] yields distinct valid designs per seed.
pub fn sample_design(eg: &EGraph, root: Id, seed: u64) -> RecExpr {
    CostTable::build_kind(eg, &CostKind::Sampled(seed)).extract(eg, root)
}

/// Knobs for one [`extract_designs`] pass.
#[derive(Debug, Clone)]
pub struct ExtractOptions {
    /// Seeded sample count (the two greedy endpoints are added on top).
    pub samples: usize,
    /// Base seed; sample `i` extracts with seed `seed + i`.
    pub seed: u64,
    /// Worker-pool width for the sample fan-out. Results are bit-identical
    /// for any width.
    pub workers: usize,
}

impl Default for ExtractOptions {
    fn default() -> Self {
        ExtractOptions { samples: 64, seed: 0, workers: default_workers() }
    }
}

/// The result of one parallel extraction pass: origin-tagged deduplicated
/// designs plus memo accounting. Analysis/evaluation is deliberately NOT
/// here — design *identity* is query-independent (so a batch of queries
/// can share one set), while costs depend on each query's `CostParams`.
#[derive(Debug, Clone)]
pub struct ExtractedSet {
    /// `(origin, design)`, greedy endpoints first then samples in seed
    /// order, deduplicated by printed form (first occurrence wins).
    pub designs: Vec<(String, RecExpr)>,
    /// Extractions requested (greedy endpoints included).
    pub requested: usize,
    /// Cost-table fixpoints reused from the cache.
    pub memo_hits: usize,
    /// Cost-table fixpoints solved by this pass.
    pub memo_misses: usize,
    /// Wall-clock of the pass.
    pub elapsed: Duration,
}

/// The full parallel, memoized extraction pass: the two greedy endpoints
/// plus `opts.samples` seeded samples, fanned out over the worker pool,
/// every fixpoint fetched through (and banked in) `cache`. Deterministic:
/// the result is bit-identical for any `opts.workers`.
pub fn extract_designs(
    eg: &EGraph,
    root: Id,
    opts: &ExtractOptions,
    cache: &ExtractCache,
) -> ExtractedSet {
    let t0 = Instant::now();
    let mut hits = 0usize;
    let mut misses = 0usize;
    let mut designs: Vec<(String, RecExpr)> = Vec::with_capacity(opts.samples + 2);
    for (kind, origin) in
        [(CostKind::Latency, "greedy-latency"), (CostKind::Area, "greedy-area")]
    {
        let (table, hit) = cache.table(eg, kind);
        if hit { hits += 1 } else { misses += 1 }
        designs.push((origin.to_string(), table.extract(eg, root)));
    }
    // One independent seeded extraction per item; `parallel_map` preserves
    // item order, so the merged stream is identical for any worker count.
    let sampled: Vec<(RecExpr, bool)> =
        parallel_map(opts.workers, (0..opts.samples).collect(), |i: &usize| {
            let seed = opts.seed.wrapping_add(*i as u64);
            let (table, hit) = cache.table(eg, CostKind::Sampled(seed));
            (table.extract(eg, root), hit)
        });
    for (i, (expr, hit)) in sampled.into_iter().enumerate() {
        if hit { hits += 1 } else { misses += 1 }
        designs.push((format!("sample-{}", opts.seed.wrapping_add(i as u64)), expr));
    }
    // Deduplicate structurally identical designs (first occurrence wins).
    let mut seen = std::collections::HashSet::new();
    designs.retain(|(_, e)| seen.insert(e.to_string()));
    ExtractedSet {
        designs,
        requested: opts.samples + 2,
        memo_hits: hits,
        memo_misses: misses,
        elapsed: t0.elapsed(),
    }
}

/// Draw `n` sampled designs plus the two greedy endpoints, analyzed under
/// `params`; deduplicate by printed form. Convenience front over
/// [`extract_designs`] with a throwaway cache.
pub fn sample_designs(eg: &EGraph, root: Id, n: usize, params: &CostParams) -> Vec<DesignPoint> {
    let cache = ExtractCache::new();
    let opts = ExtractOptions { samples: n, seed: 0, workers: default_workers() };
    let set = extract_designs(eg, root, &opts, &cache);
    analyze_points(&set.designs, params, opts.workers)
}

/// Analyze origin-tagged designs into [`DesignPoint`]s on the worker pool
/// (order-preserving). Borrows the design set so a batch of queries can
/// re-analyze one shared set without copying it per query.
pub fn analyze_points(
    designs: &[(String, RecExpr)],
    params: &CostParams,
    workers: usize,
) -> Vec<DesignPoint> {
    let items: Vec<&(String, RecExpr)> = designs.iter().collect();
    parallel_map(workers, items, |(origin, expr)| {
        let (cost, stats) = analyze(expr, params);
        DesignPoint { expr: expr.clone(), cost, stats, origin: origin.clone() }
    })
}

/// Incrementally maintained area/latency Pareto frontier: points stream in
/// via [`ParetoFrontier::insert`], which rejects dominated or duplicate
/// arrivals and evicts existing points the arrival dominates. Equivalent
/// to [`pareto_frontier`] over the same insertion order (the property
/// tests pin this), but `O(n·|frontier|)` instead of `O(n²)`.
#[derive(Debug, Clone, Default)]
pub struct ParetoFrontier {
    points: Vec<DesignPoint>,
}

impl ParetoFrontier {
    pub fn new() -> Self {
        ParetoFrontier::default()
    }

    /// Offer one point; returns whether it joined the frontier. A rejected
    /// point is dominated by (or duplicates the (area, latency) of) a
    /// current member; an accepted point evicts every member it dominates.
    pub fn insert(&mut self, p: DesignPoint) -> bool {
        let dominated_or_dup = self.points.iter().any(|q| {
            q.cost.dominates(&p.cost)
                || (q.cost.area == p.cost.area && q.cost.latency == p.cost.latency)
        });
        if dominated_or_dup {
            return false;
        }
        self.points.retain(|q| !p.cost.dominates(&q.cost));
        self.points.push(p);
        true
    }

    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// Current members (insertion order).
    pub fn points(&self) -> &[DesignPoint] {
        &self.points
    }

    /// Finish: the frontier sorted by area ascending (the order
    /// [`pareto_frontier`] produces — no two frontier members share an
    /// area, so the order is total).
    pub fn into_sorted(mut self) -> Vec<DesignPoint> {
        self.points.sort_by(|a, b| a.cost.area.total_cmp(&b.cost.area));
        self.points
    }
}

/// The area/latency Pareto frontier over a set of design points — the
/// all-vs-all collect-then-filter **reference** implementation. Serving
/// paths stream through [`ParetoFrontier`] instead; the equivalence tests
/// compare the two.
pub fn pareto_frontier(points: &[DesignPoint]) -> Vec<DesignPoint> {
    let mut frontier: Vec<DesignPoint> = Vec::new();
    for p in points {
        if points.iter().any(|q| q.cost.dominates(&p.cost)) {
            continue;
        }
        if !frontier.iter().any(|q| q.cost.area == p.cost.area && q.cost.latency == p.cost.latency)
        {
            frontier.push(p.clone());
        }
    }
    frontier.sort_by(|a, b| a.cost.area.total_cmp(&b.cost.area));
    frontier
}

/// Extraction-side run stats, the read-path sibling of
/// [`crate::egraph::RunnerReport`]: throughput, memo effectiveness and the
/// streamed frontier trajectory of one query's extraction pass.
#[derive(Debug, Clone, Default)]
pub struct ExtractReport {
    /// Extractions requested (greedy endpoints included).
    pub requested: usize,
    /// Distinct designs after deduplication.
    pub distinct: usize,
    /// Cost-table fixpoints reused from the session memo.
    pub memo_hits: usize,
    /// Cost-table fixpoints solved by this pass (0 on a fully warm memo).
    pub memo_misses: usize,
    /// Wall-clock of the extraction pass (sampling only, not evaluation).
    pub elapsed: Duration,
    /// Frontier size after each streamed insertion round (one entry per
    /// evaluated design, in arrival order).
    pub frontier_sizes: Vec<usize>,
}

impl ExtractReport {
    /// Sampling throughput (requested extractions per second).
    pub fn samples_per_sec(&self) -> f64 {
        self.requested as f64 / self.elapsed.as_secs_f64().max(1e-9)
    }

    /// Fraction of fixpoints served from the memo (1.0 = zero rebuilds).
    pub fn memo_hit_rate(&self) -> f64 {
        let total = self.memo_hits + self.memo_misses;
        if total == 0 {
            return 0.0;
        }
        self.memo_hits as f64 / total as f64
    }

    /// Final frontier size.
    pub fn frontier_size(&self) -> usize {
        self.frontier_sizes.last().copied().unwrap_or(0)
    }

    /// One-line render for CLIs and benches.
    pub fn line(&self) -> String {
        format!(
            "extraction: {} requested -> {} distinct in {:.2?} \
             ({:.0} samples/s, memo {:.0}% hit / {} built, frontier {})",
            self.requested,
            self.distinct,
            self.elapsed,
            self.samples_per_sec(),
            self.memo_hit_rate() * 100.0,
            self.memo_misses,
            self.frontier_size(),
        )
    }
}

/// High-level helper: enumerate (via a prepared e-graph) then sample
/// (parallel) then stream down to the frontier.
pub struct ParetoExplorer {
    pub samples: usize,
    pub params: CostParams,
    /// Worker-pool width for sampling + analysis (result-identical for any
    /// width).
    pub workers: usize,
}

impl Default for ParetoExplorer {
    fn default() -> Self {
        ParetoExplorer { samples: 64, params: CostParams::default(), workers: default_workers() }
    }
}

impl ParetoExplorer {
    pub fn explore(&self, eg: &EGraph, root: Id) -> (Vec<DesignPoint>, Vec<DesignPoint>) {
        let cache = ExtractCache::new();
        let opts = ExtractOptions { samples: self.samples, seed: 0, workers: self.workers };
        let set = extract_designs(eg, root, &opts, &cache);
        let pts = analyze_points(&set.designs, &self.params, self.workers);
        let mut frontier = ParetoFrontier::new();
        for p in &pts {
            frontier.insert(p.clone());
        }
        (pts, frontier.into_sorted())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::egraph::Runner;
    use crate::ir::parse_expr;
    use crate::rewrites;
    use crate::tensor::{eval_expr, Env};

    fn enumerated(src: &str, iters: usize) -> (EGraph, Id) {
        let e = parse_expr(src).unwrap();
        let mut runner = Runner::new(e, rewrites::paper_rules());
        runner.run(iters);
        let root = runner.root;
        (runner.egraph, root)
    }

    const FIG2: &str = "(invoke-relu (relu-engine 128) (input x [128]))";

    #[test]
    fn extract_returns_wellformed_equivalent() {
        let (eg, root) = enumerated(FIG2, 6);
        let ex = Extractor::new(&eg, size_cost);
        let d = ex.extract(&eg, root);
        d.typecheck().expect("extracted design must typecheck");
        // Differential: design evaluates to the same values.
        let orig = parse_expr(FIG2).unwrap();
        let a = eval_expr(&orig, &mut Env::random_for(&orig, 3)).unwrap();
        let b = eval_expr(&d, &mut Env::random_for(&d, 3)).unwrap();
        assert!(a.allclose(&b, 1e-5));
    }

    #[test]
    fn size_cost_recovers_smallest() {
        let (eg, root) = enumerated(FIG2, 6);
        let ex = Extractor::new(&eg, size_cost);
        let d = ex.extract(&eg, root);
        // The original 3-node program is the smallest member.
        assert_eq!(d.len(), 3, "{d}");
    }

    #[test]
    fn samples_are_diverse_and_all_equivalent() {
        let (eg, root) = enumerated(FIG2, 6);
        let pts = sample_designs(&eg, root, 24, &CostParams::default());
        assert!(pts.len() >= 5, "only {} distinct designs", pts.len());
        let orig = parse_expr(FIG2).unwrap();
        let want = eval_expr(&orig, &mut Env::random_for(&orig, 1)).unwrap();
        for p in &pts {
            p.expr.typecheck().unwrap();
            let got = eval_expr(&p.expr, &mut Env::random_for(&p.expr, 1)).unwrap();
            assert!(want.allclose(&got, 1e-5), "diverged: {}", p.expr);
        }
    }

    #[test]
    fn frontier_is_mutually_nondominated() {
        let (eg, root) = enumerated(FIG2, 6);
        let (pts, frontier) = ParetoExplorer::default().explore(&eg, root);
        assert!(!frontier.is_empty());
        assert!(frontier.len() <= pts.len());
        for a in &frontier {
            for b in &frontier {
                assert!(!a.cost.dominates(&b.cost) || a.cost == b.cost);
            }
        }
        // And the frontier spans a real area range (diversity of splits).
        if frontier.len() >= 2 {
            assert!(frontier[0].cost.area < frontier.last().unwrap().cost.area);
        }
    }

    #[test]
    fn extract_designs_is_identical_across_worker_counts() {
        let (eg, root) = enumerated(FIG2, 6);
        let render = |workers: usize| {
            let cache = ExtractCache::new();
            let opts = ExtractOptions { samples: 16, seed: 3, workers };
            extract_designs(&eg, root, &opts, &cache)
                .designs
                .into_iter()
                .map(|(origin, e)| (origin, e.to_string()))
                .collect::<Vec<_>>()
        };
        let one = render(1);
        assert!(one.len() >= 3);
        assert_eq!(render(2), one);
        assert_eq!(render(4), one);
    }

    #[test]
    fn cache_hits_on_unchanged_graph_and_invalidates_on_mutation() {
        let (mut eg, root) = enumerated(FIG2, 6);
        let cache = ExtractCache::new();
        let opts = ExtractOptions { samples: 8, seed: 0, workers: 2 };
        let cold = extract_designs(&eg, root, &opts, &cache);
        assert_eq!(cold.memo_misses, opts.samples + 2);
        assert_eq!(cold.memo_hits, 0);

        // Warm pass: zero fixpoint rebuilds, identical designs.
        let warm = extract_designs(&eg, root, &opts, &cache);
        assert_eq!(warm.memo_misses, 0, "unchanged graph must serve from the memo");
        assert_eq!(warm.memo_hits, opts.samples + 2);
        let strs = |set: &ExtractedSet| {
            set.designs.iter().map(|(_, e)| e.to_string()).collect::<Vec<_>>()
        };
        assert_eq!(strs(&cold), strs(&warm));

        // Mutating the e-graph bumps the epoch and invalidates the cache.
        let before = eg.epoch();
        eg.add_expr(&parse_expr("(input fresh [7])").unwrap());
        assert!(eg.epoch() > before);
        let cool = extract_designs(&eg, root, &opts, &cache);
        assert_eq!(cool.memo_misses, opts.samples + 2);
        assert_eq!(strs(&cool), strs(&warm), "an unrelated input must not change designs");
    }

    #[test]
    fn incremental_cost_tables_match_scratch_after_mutation() {
        // Warm tables against a partially-enumerated graph, mutate it
        // (adds + a union), then check the stale-seeded incremental
        // re-solve lands on the same cost fixpoint as a scratch build.
        let (mut eg, root) = enumerated(FIG2, 4);
        let kinds = [CostKind::Latency, CostKind::Area, CostKind::Size, CostKind::Sampled(7)];
        let cache = ExtractCache::new();
        for k in &kinds {
            cache.table(&eg, k.clone());
        }
        let alias = eg.add_expr(&parse_expr("(relu (input x [128]))").unwrap());
        eg.union(root, alias);
        eg.rebuild();
        for k in &kinds {
            let (incr, hit) = cache.table(&eg, k.clone());
            assert!(!hit, "epoch bumped, must re-solve");
            let scratch = CostTable::build_kind(&eg, k);
            assert!(costs_agree(&incr, &scratch, &eg), "diverged for {k:?}");
            // And the table is re-memoized at the new epoch.
            let (_, rehit) = cache.table(&eg, k.clone());
            assert!(rehit);
        }
    }

    #[test]
    fn build_incremental_with_empty_dirty_set_is_identity() {
        let (eg, _) = enumerated(FIG2, 4);
        let scratch = CostTable::build_kind(&eg, &CostKind::Latency);
        let incr = CostTable::build_kind_incremental(&eg, &CostKind::Latency, &scratch, &[]);
        assert!(costs_agree(&incr, &scratch, &eg));
    }

    #[test]
    fn sampled_table_memo_is_bounded_fifo() {
        // A long-lived cache cycling through seeds must not grow without
        // bound: sampled tables are FIFO-evicted past MAX_SAMPLED_TABLES.
        let e = parse_expr(FIG2).unwrap();
        let mut eg = EGraph::new();
        eg.add_expr(&e);
        let cache = ExtractCache::new();
        let n = MAX_SAMPLED_TABLES as u64 + 44;
        for seed in 0..n {
            cache.table(&eg, CostKind::Sampled(seed));
        }
        assert!(cache.len() <= MAX_SAMPLED_TABLES);
        // Newest seed retained; the oldest were evicted.
        let (_, hit_new) = cache.table(&eg, CostKind::Sampled(n - 1));
        assert!(hit_new);
        let (_, hit_old) = cache.table(&eg, CostKind::Sampled(0));
        assert!(!hit_old, "seed 0 must have been FIFO-evicted");
    }

    #[test]
    fn sample_design_matches_sampled_cost_table() {
        // `sample_design` and the memoized `CostKind::Sampled` path are the
        // same extraction.
        let (eg, root) = enumerated(FIG2, 6);
        let cache = ExtractCache::new();
        for seed in [0u64, 1, 9] {
            let direct = sample_design(&eg, root, seed);
            let (table, _) = cache.table(&eg, CostKind::Sampled(seed));
            assert_eq!(direct.to_string(), table.extract(&eg, root).to_string());
        }
    }

    #[test]
    fn streaming_frontier_matches_reference_on_samples() {
        let (eg, root) = enumerated(FIG2, 6);
        let pts = sample_designs(&eg, root, 24, &CostParams::default());
        let mut streaming = ParetoFrontier::new();
        for p in &pts {
            streaming.insert(p.clone());
        }
        let stream = streaming
            .into_sorted()
            .iter()
            .map(|p| (p.cost.area, p.cost.latency, p.origin.clone()))
            .collect::<Vec<_>>();
        let reference = pareto_frontier(&pts)
            .iter()
            .map(|p| (p.cost.area, p.cost.latency, p.origin.clone()))
            .collect::<Vec<_>>();
        assert_eq!(stream, reference);
    }

    #[test]
    fn extract_report_rates() {
        let r = ExtractReport {
            requested: 10,
            distinct: 7,
            memo_hits: 8,
            memo_misses: 2,
            elapsed: Duration::from_millis(5),
            frontier_sizes: vec![1, 2, 2, 3],
        };
        assert!((r.memo_hit_rate() - 0.8).abs() < 1e-12);
        assert_eq!(r.frontier_size(), 3);
        assert!(r.samples_per_sec() > 0.0);
        assert!(r.line().contains("frontier 3"));
    }
}
