//! Extraction: picking concrete designs back out of the e-graph.
//!
//! The paper explicitly scopes extraction out ("the extraction procedure is
//! out of the scope of this early work") — but the evaluation methodology
//! (§3 diversity + usefulness) needs concrete design points, so we
//! implement it as a first-class extension:
//!
//! * [`Extractor`] — classic bottom-up fixpoint extraction with a pluggable
//!   per-node cost function (monotone in child costs ⇒ termination and
//!   optimality for tree costs);
//! * [`latency_cost`] / [`size_cost`] — built-in cost functions;
//! * [`sample_designs`] — randomized-cost extraction: each sample perturbs
//!   node costs with seeded noise, yielding a *diverse* set of valid
//!   designs (the paper's diversity experiment);
//! * [`ParetoExplorer`] — samples + greedy endpoints, evaluated with the
//!   analytic models, reduced to the area/latency Pareto frontier (the
//!   usefulness experiment).

use crate::cost::{analyze, CostParams, DesignCost, DesignStats};
use crate::egraph::{EGraph, Id};
use crate::ir::{Node, Op, RecExpr};
use crate::prop::Rng;
use crate::fx::FxHashMap as HashMap;

/// A per-node extraction cost: receives the candidate e-node and the cost
/// of each child *class* (already minimized); returns the node's total.
pub type NodeCost<'a> = dyn Fn(&EGraph, &Node, &dyn Fn(Id) -> f64) -> f64 + 'a;

/// Bottom-up fixpoint extractor.
pub struct Extractor<'c> {
    cost_fn: Box<NodeCost<'c>>,
    /// class -> (best cost, best node)
    best: HashMap<Id, (f64, Node)>,
}

impl<'c> Extractor<'c> {
    /// Run the fixpoint against `eg` with `cost_fn`.
    pub fn new(eg: &EGraph, cost_fn: impl Fn(&EGraph, &Node, &dyn Fn(Id) -> f64) -> f64 + 'c) -> Self {
        let mut ex = Extractor { cost_fn: Box::new(cost_fn), best: HashMap::default() };
        ex.fixpoint(eg);
        ex
    }

    /// Worklist fixpoint: when a class's best cost improves, only the
    /// e-nodes that reference it are re-evaluated (near-linear in
    /// practice; the naive repeat-all-passes version is quadratic and
    /// dominates exploration time on large e-graphs).
    fn fixpoint(&mut self, eg: &EGraph) {
        // Snapshot nodes and build a child -> referencing-nodes index.
        let mut nodes: Vec<(Id, Node)> = Vec::new();
        for class in eg.classes() {
            for node in &class.nodes {
                nodes.push((class.id, node.clone()));
            }
        }
        let mut parents: HashMap<Id, Vec<usize>> = HashMap::default();
        for (i, (_, node)) in nodes.iter().enumerate() {
            for &c in &node.children {
                parents.entry(eg.find_ref(c)).or_default().push(i);
            }
        }
        // Seed with every node; drain with re-push on improvement.
        let mut queue: std::collections::VecDeque<usize> = (0..nodes.len()).collect();
        let mut queued: Vec<bool> = vec![true; nodes.len()];
        while let Some(i) = queue.pop_front() {
            queued[i] = false;
            let (cid, node) = &nodes[i];
            let ready =
                node.children.iter().all(|&c| self.best.contains_key(&eg.find_ref(c)));
            if !ready {
                continue;
            }
            let lookup = |id: Id| self.best[&eg.find_ref(id)].0;
            let cost = (self.cost_fn)(eg, node, &lookup);
            let improves = self.best.get(cid).map_or(true, |(old, _)| cost < *old);
            if improves {
                self.best.insert(*cid, (cost, node.clone()));
                if let Some(ps) = parents.get(cid) {
                    for &p in ps {
                        if !queued[p] {
                            queued[p] = true;
                            queue.push_back(p);
                        }
                    }
                }
            }
        }
    }

    /// Best cost of a class, if extractable.
    pub fn cost(&self, eg: &EGraph, id: Id) -> Option<f64> {
        self.best.get(&eg.find_ref(id)).map(|(c, _)| *c)
    }

    /// Extract the best design rooted at `root`.
    pub fn extract(&self, eg: &EGraph, root: Id) -> RecExpr {
        let mut expr = RecExpr::new();
        let mut memo: HashMap<Id, Id> = HashMap::default();
        let id = self.extract_rec(eg, eg.find_ref(root), &mut expr, &mut memo);
        debug_assert_eq!(id, expr.root());
        expr
    }

    fn extract_rec(
        &self,
        eg: &EGraph,
        id: Id,
        expr: &mut RecExpr,
        memo: &mut HashMap<Id, Id>,
    ) -> Id {
        let id = eg.find_ref(id);
        if let Some(&done) = memo.get(&id) {
            return done;
        }
        let (_, node) = self.best.get(&id).expect("extract: class has no finite cost");
        let children: Vec<Id> = node
            .children
            .iter()
            .map(|&c| self.extract_rec(eg, c, expr, memo))
            .collect();
        let new_id = expr.add(Node::new(node.op.clone(), children));
        memo.insert(id, new_id);
        new_id
    }
}

/// Node-count cost (smallest term).
pub fn size_cost(_eg: &EGraph, node: &Node, child: &dyn Fn(Id) -> f64) -> f64 {
    1.0 + node.children.iter().map(|&c| child(c)).sum::<f64>()
}

/// A local approximation of the latency model in [`crate::cost`]: enough to
/// steer greedy extraction toward fast designs (the exact model runs on the
/// extracted tree afterwards).
pub fn latency_cost(eg: &EGraph, node: &Node, child: &dyn Fn(Id) -> f64) -> f64 {
    let p = CostParams::default();
    let kids: f64 = node.children.iter().map(|&c| child(c)).sum();
    let out_elems = |id: Id| -> f64 {
        eg.ty(id).shape().map(|s| s.numel() as f64).unwrap_or(0.0)
    };
    match &node.op {
        op if op.is_invoke() => {
            let mut io = 0.0;
            for &a in &node.children[1..] {
                io += out_elems(a);
            }
            kids + p.startup + io / p.port_width
        }
        Op::SchedLoop { extent, .. } => *extent as f64 * (kids + p.loop_overhead),
        Op::SchedPar { extent, .. } => kids + (*extent as f64).log2().ceil() * p.loop_overhead,
        Op::SchedReduce { extent, .. } => *extent as f64 * (kids + p.loop_overhead),
        Op::Buffer { .. } | Op::DblBuffer { .. } => kids + 1.0,
        Op::Pad2d { .. } | Op::Im2Col { .. } => kids + 4.0,
        op if op.is_relay() => kids + 1e7, // host fallback: avoid at all costs
        _ => kids,
    }
}

/// Area-leaning cost: engine MACs dominate (steers toward small shared
/// engines and deep loops).
pub fn area_cost(_eg: &EGraph, node: &Node, child: &dyn Fn(Id) -> f64) -> f64 {
    let kids: f64 = node.children.iter().map(|&c| child(c)).sum();
    match &node.op {
        op if op.is_engine() => op.engine_macs() as f64,
        // NOTE: tree-cost approximation double-counts shared engines; the
        // exact DAG-aware area is computed on the extracted tree.
        Op::SchedPar { extent, .. } => kids * *extent as f64,
        op if op.is_relay() => kids + 1e7,
        _ => kids + 0.001, // slight size pressure
    }
}

/// One extracted design point with its evaluation.
#[derive(Debug, Clone)]
pub struct DesignPoint {
    pub expr: RecExpr,
    pub cost: DesignCost,
    pub stats: DesignStats,
    /// How this point was produced (greedy-latency / greedy-area / sample-i).
    pub origin: String,
}

/// Randomized-cost extraction: seeded multiplicative noise on
/// [`latency_cost`] yields distinct valid designs per seed.
pub fn sample_design(eg: &EGraph, root: Id, seed: u64) -> RecExpr {
    // Per-node deterministic noise (cheap structural hash — this runs in
    // the extraction inner loop).
    let noise = move |node: &Node| {
        use std::hash::{Hash, Hasher};
        let mut h = std::collections::hash_map::DefaultHasher::new();
        seed.hash(&mut h);
        node.hash(&mut h);
        let mut r = Rng::new(h.finish() | 1);
        // Noise in [0.25, 4.0) — enough to flip most local decisions.
        0.25 * (1.0 + 15.0 * r.f64())
    };
    let ex = Extractor::new(eg, move |eg, node, child| {
        latency_cost(eg, node, child) * noise(node) + 1.0
    });
    ex.extract(eg, root)
}

/// Draw `n` sampled designs plus the two greedy endpoints; deduplicate by
/// printed form.
pub fn sample_designs(eg: &EGraph, root: Id, n: usize, params: &CostParams) -> Vec<DesignPoint> {
    let mut out: Vec<DesignPoint> = Vec::new();
    let mut seen = std::collections::HashSet::new();
    let mut push = |expr: RecExpr, origin: String, out: &mut Vec<DesignPoint>| {
        let key = expr.to_string();
        if seen.insert(key) {
            let (cost, stats) = analyze(&expr, params);
            out.push(DesignPoint { expr, cost, stats, origin });
        }
    };
    push(
        Extractor::new(eg, latency_cost).extract(eg, root),
        "greedy-latency".into(),
        &mut out,
    );
    push(Extractor::new(eg, area_cost).extract(eg, root), "greedy-area".into(), &mut out);
    for i in 0..n {
        push(sample_design(eg, root, i as u64), format!("sample-{i}"), &mut out);
    }
    out
}

/// The area/latency Pareto frontier over a set of design points.
pub fn pareto_frontier(points: &[DesignPoint]) -> Vec<DesignPoint> {
    let mut frontier: Vec<DesignPoint> = Vec::new();
    for p in points {
        if points.iter().any(|q| q.cost.dominates(&p.cost)) {
            continue;
        }
        if !frontier.iter().any(|q| q.cost.area == p.cost.area && q.cost.latency == p.cost.latency)
        {
            frontier.push(p.clone());
        }
    }
    frontier.sort_by(|a, b| a.cost.area.total_cmp(&b.cost.area));
    frontier
}

/// High-level helper: enumerate (via a prepared e-graph) then sample then
/// reduce to the frontier.
pub struct ParetoExplorer {
    pub samples: usize,
    pub params: CostParams,
}

impl Default for ParetoExplorer {
    fn default() -> Self {
        ParetoExplorer { samples: 64, params: CostParams::default() }
    }
}

impl ParetoExplorer {
    pub fn explore(&self, eg: &EGraph, root: Id) -> (Vec<DesignPoint>, Vec<DesignPoint>) {
        let pts = sample_designs(eg, root, self.samples, &self.params);
        let frontier = pareto_frontier(&pts);
        (pts, frontier)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::egraph::Runner;
    use crate::ir::parse_expr;
    use crate::rewrites;
    use crate::tensor::{eval_expr, Env};

    fn enumerated(src: &str, iters: usize) -> (EGraph, Id) {
        let e = parse_expr(src).unwrap();
        let mut runner = Runner::new(e, rewrites::paper_rules());
        runner.run(iters);
        let root = runner.root;
        (runner.egraph, root)
    }

    const FIG2: &str = "(invoke-relu (relu-engine 128) (input x [128]))";

    #[test]
    fn extract_returns_wellformed_equivalent() {
        let (eg, root) = enumerated(FIG2, 6);
        let ex = Extractor::new(&eg, size_cost);
        let d = ex.extract(&eg, root);
        d.typecheck().expect("extracted design must typecheck");
        // Differential: design evaluates to the same values.
        let orig = parse_expr(FIG2).unwrap();
        let a = eval_expr(&orig, &mut Env::random_for(&orig, 3)).unwrap();
        let b = eval_expr(&d, &mut Env::random_for(&d, 3)).unwrap();
        assert!(a.allclose(&b, 1e-5));
    }

    #[test]
    fn size_cost_recovers_smallest() {
        let (eg, root) = enumerated(FIG2, 6);
        let ex = Extractor::new(&eg, size_cost);
        let d = ex.extract(&eg, root);
        // The original 3-node program is the smallest member.
        assert_eq!(d.len(), 3, "{d}");
    }

    #[test]
    fn samples_are_diverse_and_all_equivalent() {
        let (eg, root) = enumerated(FIG2, 6);
        let pts = sample_designs(&eg, root, 24, &CostParams::default());
        assert!(pts.len() >= 5, "only {} distinct designs", pts.len());
        let orig = parse_expr(FIG2).unwrap();
        let want = eval_expr(&orig, &mut Env::random_for(&orig, 1)).unwrap();
        for p in &pts {
            p.expr.typecheck().unwrap();
            let got = eval_expr(&p.expr, &mut Env::random_for(&p.expr, 1)).unwrap();
            assert!(want.allclose(&got, 1e-5), "diverged: {}", p.expr);
        }
    }

    #[test]
    fn frontier_is_mutually_nondominated() {
        let (eg, root) = enumerated(FIG2, 6);
        let (pts, frontier) = ParetoExplorer::default().explore(&eg, root);
        assert!(!frontier.is_empty());
        assert!(frontier.len() <= pts.len());
        for a in &frontier {
            for b in &frontier {
                assert!(!a.cost.dominates(&b.cost) || a.cost == b.cost);
            }
        }
        // And the frontier spans a real area range (diversity of splits).
        if frontier.len() >= 2 {
            assert!(frontier[0].cost.area < frontier.last().unwrap().cost.area);
        }
    }
}
