//! The operator set of EngineIR.
//!
//! Design notes:
//!
//! * Scalar parameters that rewrites must *compute over* (engine sizes,
//!   schedule extents, slice lengths) are stored **in the op itself** rather
//!   than as child e-nodes. This keeps e-nodes small, makes hashcons sharing
//!   of engine declarations exact (the paper's "engine reuse across call
//!   sites" falls out of structural equality), and lets rewrites synthesize
//!   new parameters (`m/2`, `(oh-1)*stride+kh`, …) directly.
//! * Only *dynamic indices* — slice starts that depend on a schedule's loop
//!   variable — are child expressions (`Int` / `LVar` / `IMul` / `IAdd`).
//! * Schedules bind **named** loop variables ([`Op::SchedLoop`] etc. carry a
//!   [`Symbol`]); rewrites always bind fresh names, so there is no capture
//!   and no de Bruijn shifting inside the e-graph.

use super::shape::Shape;
use super::symbol::Symbol;
use std::fmt;

/// Storage kind for explicit buffer materialization points.
#[derive(Copy, Clone, PartialEq, Eq, Hash, Debug, PartialOrd, Ord)]
pub enum BufKind {
    /// On-chip scratchpad (VMEM/BRAM-class): fast, area-costly.
    Sram,
    /// Off-chip memory (HBM/DRAM-class): free area, slow.
    Dram,
}

impl BufKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            BufKind::Sram => "sram",
            BufKind::Dram => "dram",
        }
    }
}

/// An EngineIR operator. See the module docs for the sub-language split
/// (index scalars / Relay ops / engines / invocations / schedules / storage).
#[derive(Clone, PartialEq, Eq, Hash, Debug)]
pub enum Op {
    // ------------------------------------------------------------------
    // Index scalars (children of `SliceAx` starts only)
    // ------------------------------------------------------------------
    /// Integer literal.
    Int(i64),
    /// Reference to an enclosing schedule's loop variable.
    LVar(Symbol),
    /// Integer multiply; children `[a, b]`.
    IMul,
    /// Integer add; children `[a, b]`.
    IAdd,

    // ------------------------------------------------------------------
    // Workload tensors (leaves)
    // ------------------------------------------------------------------
    /// Named workload input with static shape.
    Input(Symbol, Shape),
    /// Named trained parameter with static shape.
    Weight(Symbol, Shape),

    // ------------------------------------------------------------------
    // Relay-level operators (pre-reification; N=1 inference, CHW layout)
    // ------------------------------------------------------------------
    /// 2-D convolution; children `[x:(C,H,W), w:(K,C,KH,KW)]`.
    Conv2d { stride: usize, pad: usize },
    /// Dense / fully-connected; children `[x:(M,K), w:(K,N)]`.
    Dense,
    /// Elementwise ReLU; children `[x]` (any shape).
    Relu,
    /// Bias add; children `[x, b]`, `b` broadcast along `x`'s leading dim
    /// (rank-3 `x`) or trailing dim (rank-2 `x`).
    BiasAdd,
    /// Elementwise add; children `[x, y]` (same shape).
    EAdd,
    /// Max pooling; children `[x:(C,H,W)]`.
    MaxPool2d { k: usize, stride: usize },
    /// Flatten to `(1, numel)`; children `[x]`.
    Flatten,
    /// Global average pool `(C,H,W) -> (C)`; children `[x]`.
    GlobalAvgPool,

    // ------------------------------------------------------------------
    // Hardware engine declarations (leaves; paper Fig. 1)
    // ------------------------------------------------------------------
    /// Matrix-multiply engine computing `(m,k) @ (k,n)`.
    MmEngine { m: usize, k: usize, n: usize },
    /// Fused matmul+ReLU engine (extension rewrite R7).
    MmReluEngine { m: usize, k: usize, n: usize },
    /// `w`-wide vector ReLU unit (paper Fig. 2).
    ReluEngine { w: usize },
    /// `w`-wide vector adder.
    AddEngine { w: usize },
    /// Direct convolution engine producing an `(k, oh, ow)` output tile from
    /// a `(c, ih, iw)` input tile with a square `kh` kernel (paper Fig. 1's
    /// `conv_engine<H, W, C, K>`).
    ConvEngine { oh: usize, ow: usize, c: usize, k: usize, kh: usize, stride: usize },
    /// Max-pool engine producing `(c, oh, ow)` from `(c, ih, iw)`.
    PoolEngine { oh: usize, ow: usize, c: usize, k: usize, stride: usize },

    // ------------------------------------------------------------------
    // Engine invocations: children `[engine, tensor args...]`
    // ------------------------------------------------------------------
    /// `[e:MmEngine, a:(m,k), b:(k,n)] -> (m,n)`.
    InvokeMm,
    /// `[e:MmReluEngine, a, b] -> relu(a@b)`.
    InvokeMmRelu,
    /// `[e:ReluEngine, x:(w,)] -> (w,)`.
    InvokeRelu,
    /// `[e:AddEngine, x:(w,), y:(w,)] -> (w,)`.
    InvokeAdd,
    /// `[e:ConvEngine, x:(c,ih,iw), w:(k,c,kh,kh)] -> (k,oh,ow)`.
    InvokeConv,
    /// `[e:PoolEngine, x:(c,ih,iw)] -> (c,oh,ow)`.
    InvokePool,

    // ------------------------------------------------------------------
    // Software schedules: children `[body]`
    // ------------------------------------------------------------------
    /// Sequential loop: run `body` `extent` times (binding `var` to
    /// `0..extent`), concatenating results along `axis`. One engine
    /// instance, time-multiplexed — paper Fig. 2 rewrite 1.
    SchedLoop { var: Symbol, axis: usize, extent: usize },
    /// Parallel map: same semantics as `SchedLoop`, but `extent` hardware
    /// instances run concurrently — paper Fig. 2 rewrite 2.
    SchedPar { var: Symbol, axis: usize, extent: usize },
    /// Reduction schedule: run `body` `extent` times and sum the results
    /// elementwise (used by matmul K-splitting).
    SchedReduce { var: Symbol, extent: usize },

    // ------------------------------------------------------------------
    // Data movement & storage
    // ------------------------------------------------------------------
    /// Slice `len` elements along `axis`; children `[start:index, x]`.
    SliceAx { axis: usize, len: usize },
    /// Reshape to a static shape; children `[x]`.
    Reshape(Shape),
    /// Broadcast a 1-D tensor to `shape` along dim 0 (rank-3 result) or
    /// dim 1 (rank-2 result); children `[b]`.
    Bcast(Shape),
    /// Zero-pad H and W of a `(C,H,W)` tensor; children `[x]`.
    Pad2d { pad: usize },
    /// im2col: `(c,ih,iw) -> (c*kh*kh, oh*ow)` patch matrix; children `[x]`.
    Im2Col { kh: usize, stride: usize },
    /// Materialize the child into an explicit storage buffer.
    Buffer { kind: BufKind },
    /// Double-buffered materialization (pipelining rewrite R6).
    DblBuffer { kind: BufKind },
}

/// Coarse operator classification used by pattern matching ([`OpKind`]
/// matchers bind any op of a kind) and by cost/statistics code.
#[derive(Copy, Clone, PartialEq, Eq, Hash, Debug)]
pub enum OpKind {
    Int,
    LVar,
    IMul,
    IAdd,
    Input,
    Weight,
    Conv2d,
    Dense,
    Relu,
    BiasAdd,
    EAdd,
    MaxPool2d,
    Flatten,
    GlobalAvgPool,
    MmEngine,
    MmReluEngine,
    ReluEngine,
    AddEngine,
    ConvEngine,
    PoolEngine,
    InvokeMm,
    InvokeMmRelu,
    InvokeRelu,
    InvokeAdd,
    InvokeConv,
    InvokePool,
    SchedLoop,
    SchedPar,
    SchedReduce,
    SliceAx,
    Reshape,
    Bcast,
    Pad2d,
    Im2Col,
    Buffer,
    DblBuffer,
}

impl Op {
    /// The coarse kind of this op.
    pub fn kind(&self) -> OpKind {
        match self {
            Op::Int(_) => OpKind::Int,
            Op::LVar(_) => OpKind::LVar,
            Op::IMul => OpKind::IMul,
            Op::IAdd => OpKind::IAdd,
            Op::Input(..) => OpKind::Input,
            Op::Weight(..) => OpKind::Weight,
            Op::Conv2d { .. } => OpKind::Conv2d,
            Op::Dense => OpKind::Dense,
            Op::Relu => OpKind::Relu,
            Op::BiasAdd => OpKind::BiasAdd,
            Op::EAdd => OpKind::EAdd,
            Op::MaxPool2d { .. } => OpKind::MaxPool2d,
            Op::Flatten => OpKind::Flatten,
            Op::GlobalAvgPool => OpKind::GlobalAvgPool,
            Op::MmEngine { .. } => OpKind::MmEngine,
            Op::MmReluEngine { .. } => OpKind::MmReluEngine,
            Op::ReluEngine { .. } => OpKind::ReluEngine,
            Op::AddEngine { .. } => OpKind::AddEngine,
            Op::ConvEngine { .. } => OpKind::ConvEngine,
            Op::PoolEngine { .. } => OpKind::PoolEngine,
            Op::InvokeMm => OpKind::InvokeMm,
            Op::InvokeMmRelu => OpKind::InvokeMmRelu,
            Op::InvokeRelu => OpKind::InvokeRelu,
            Op::InvokeAdd => OpKind::InvokeAdd,
            Op::InvokeConv => OpKind::InvokeConv,
            Op::InvokePool => OpKind::InvokePool,
            Op::SchedLoop { .. } => OpKind::SchedLoop,
            Op::SchedPar { .. } => OpKind::SchedPar,
            Op::SchedReduce { .. } => OpKind::SchedReduce,
            Op::SliceAx { .. } => OpKind::SliceAx,
            Op::Reshape(_) => OpKind::Reshape,
            Op::Bcast(_) => OpKind::Bcast,
            Op::Pad2d { .. } => OpKind::Pad2d,
            Op::Im2Col { .. } => OpKind::Im2Col,
            Op::Buffer { .. } => OpKind::Buffer,
            Op::DblBuffer { .. } => OpKind::DblBuffer,
        }
    }

    /// Number of children this op expects, if fixed (all EngineIR ops have
    /// fixed arity; this is `None` only for future variadic ops).
    pub fn arity(&self) -> Option<usize> {
        Some(match self.kind() {
            OpKind::Int
            | OpKind::LVar
            | OpKind::Input
            | OpKind::Weight
            | OpKind::MmEngine
            | OpKind::MmReluEngine
            | OpKind::ReluEngine
            | OpKind::AddEngine
            | OpKind::ConvEngine
            | OpKind::PoolEngine => 0,
            OpKind::Relu
            | OpKind::Flatten
            | OpKind::GlobalAvgPool
            | OpKind::MaxPool2d
            | OpKind::Reshape
            | OpKind::Bcast
            | OpKind::Pad2d
            | OpKind::Im2Col
            | OpKind::Buffer
            | OpKind::DblBuffer
            | OpKind::SchedLoop
            | OpKind::SchedPar
            | OpKind::SchedReduce => 1,
            OpKind::IMul
            | OpKind::IAdd
            | OpKind::Conv2d
            | OpKind::Dense
            | OpKind::BiasAdd
            | OpKind::EAdd
            | OpKind::InvokeRelu
            | OpKind::InvokePool
            | OpKind::SliceAx => 2,
            OpKind::InvokeMm
            | OpKind::InvokeMmRelu
            | OpKind::InvokeAdd
            | OpKind::InvokeConv => 3,
        })
    }

    /// True for hardware engine declarations.
    pub fn is_engine(&self) -> bool {
        matches!(
            self.kind(),
            OpKind::MmEngine
                | OpKind::MmReluEngine
                | OpKind::ReluEngine
                | OpKind::AddEngine
                | OpKind::ConvEngine
                | OpKind::PoolEngine
        )
    }

    /// True for engine invocations.
    pub fn is_invoke(&self) -> bool {
        matches!(
            self.kind(),
            OpKind::InvokeMm
                | OpKind::InvokeMmRelu
                | OpKind::InvokeRelu
                | OpKind::InvokeAdd
                | OpKind::InvokeConv
                | OpKind::InvokePool
        )
    }

    /// True for software schedule combinators.
    pub fn is_sched(&self) -> bool {
        matches!(self.kind(), OpKind::SchedLoop | OpKind::SchedPar | OpKind::SchedReduce)
    }

    /// True for Relay-level (unreified) operators.
    pub fn is_relay(&self) -> bool {
        matches!(
            self.kind(),
            OpKind::Conv2d
                | OpKind::Dense
                | OpKind::Relu
                | OpKind::BiasAdd
                | OpKind::EAdd
                | OpKind::MaxPool2d
                | OpKind::Flatten
                | OpKind::GlobalAvgPool
        )
    }

    /// Multiply–accumulate count of one invocation of an engine declaration
    /// (0 for non-engines). The basis of the area and latency models.
    pub fn engine_macs(&self) -> u64 {
        match *self {
            Op::MmEngine { m, k, n } | Op::MmReluEngine { m, k, n } => (m * k * n) as u64,
            Op::ReluEngine { w } | Op::AddEngine { w } => w as u64,
            Op::ConvEngine { oh, ow, c, k, kh, .. } => (oh * ow * c * k * kh * kh) as u64,
            Op::PoolEngine { oh, ow, c, k, .. } => (oh * ow * c * k * k) as u64,
            _ => 0,
        }
    }
}

impl fmt::Display for Op {
    /// Head symbol used by the s-expression printer/parser.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Op::Int(v) => write!(f, "{v}"),
            Op::LVar(s) => write!(f, "(lvar {s})"),
            Op::IMul => write!(f, "imul"),
            Op::IAdd => write!(f, "iadd"),
            Op::Input(s, sh) => write!(f, "(input {s}{sh})"),
            Op::Weight(s, sh) => write!(f, "(weight {s}{sh})"),
            Op::Conv2d { stride, pad } => write!(f, "conv2d[s{stride},p{pad}]"),
            Op::Dense => write!(f, "dense"),
            Op::Relu => write!(f, "relu"),
            Op::BiasAdd => write!(f, "bias-add"),
            Op::EAdd => write!(f, "eadd"),
            Op::MaxPool2d { k, stride } => write!(f, "maxpool2d[k{k},s{stride}]"),
            Op::Flatten => write!(f, "flatten"),
            Op::GlobalAvgPool => write!(f, "gap"),
            Op::MmEngine { m, k, n } => write!(f, "(mm-engine {m} {k} {n})"),
            Op::MmReluEngine { m, k, n } => write!(f, "(mm-relu-engine {m} {k} {n})"),
            Op::ReluEngine { w } => write!(f, "(relu-engine {w})"),
            Op::AddEngine { w } => write!(f, "(add-engine {w})"),
            Op::ConvEngine { oh, ow, c, k, kh, stride } => {
                write!(f, "(conv-engine {oh} {ow} {c} {k} {kh} {stride})")
            }
            Op::PoolEngine { oh, ow, c, k, stride } => {
                write!(f, "(pool-engine {oh} {ow} {c} {k} {stride})")
            }
            Op::InvokeMm => write!(f, "invoke-mm"),
            Op::InvokeMmRelu => write!(f, "invoke-mm-relu"),
            Op::InvokeRelu => write!(f, "invoke-relu"),
            Op::InvokeAdd => write!(f, "invoke-add"),
            Op::InvokeConv => write!(f, "invoke-conv"),
            Op::InvokePool => write!(f, "invoke-pool"),
            Op::SchedLoop { var, axis, extent } => {
                write!(f, "sched-loop[{var},a{axis},x{extent}]")
            }
            Op::SchedPar { var, axis, extent } => {
                write!(f, "sched-par[{var},a{axis},x{extent}]")
            }
            Op::SchedReduce { var, extent } => write!(f, "sched-reduce[{var},x{extent}]"),
            Op::SliceAx { axis, len } => write!(f, "slice[a{axis},l{len}]"),
            Op::Reshape(sh) => write!(f, "reshape{sh}"),
            Op::Bcast(sh) => write!(f, "bcast{sh}"),
            Op::Pad2d { pad } => write!(f, "pad2d[{pad}]"),
            Op::Im2Col { kh, stride } => write!(f, "im2col[k{kh},s{stride}]"),
            Op::Buffer { kind } => write!(f, "buffer[{}]", kind.as_str()),
            Op::DblBuffer { kind } => write!(f, "dbl-buffer[{}]", kind.as_str()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arity_matches_docs() {
        assert_eq!(Op::InvokeMm.arity(), Some(3));
        assert_eq!(Op::Relu.arity(), Some(1));
        assert_eq!(Op::MmEngine { m: 4, k: 4, n: 4 }.arity(), Some(0));
        assert_eq!(Op::SliceAx { axis: 0, len: 4 }.arity(), Some(2));
    }

    #[test]
    fn engine_classification() {
        assert!(Op::ReluEngine { w: 8 }.is_engine());
        assert!(!Op::InvokeRelu.is_engine());
        assert!(Op::InvokeRelu.is_invoke());
        assert!(Op::SchedLoop { var: Symbol::new("i"), axis: 0, extent: 2 }.is_sched());
        assert!(Op::Dense.is_relay());
    }

    #[test]
    fn engine_macs_scale_with_params() {
        let small = Op::MmEngine { m: 4, k: 4, n: 4 }.engine_macs();
        let big = Op::MmEngine { m: 8, k: 4, n: 4 }.engine_macs();
        assert_eq!(big, 2 * small);
        assert_eq!(Op::ReluEngine { w: 128 }.engine_macs(), 128);
    }

    #[test]
    fn ops_hash_structurally() {
        use std::collections::HashSet;
        let mut s = HashSet::new();
        s.insert(Op::MmEngine { m: 16, k: 16, n: 16 });
        // Same parameters -> same engine declaration -> shared hardware.
        assert!(s.contains(&Op::MmEngine { m: 16, k: 16, n: 16 }));
        assert!(!s.contains(&Op::MmEngine { m: 16, k: 16, n: 8 }));
    }
}
