//! The operator set of EngineIR.
//!
//! Design notes:
//!
//! * Scalar parameters that rewrites must *compute over* (engine sizes,
//!   schedule extents, slice lengths) are stored **in the op itself** rather
//!   than as child e-nodes. This keeps e-nodes small, makes hashcons sharing
//!   of engine declarations exact (the paper's "engine reuse across call
//!   sites" falls out of structural equality), and lets rewrites synthesize
//!   new parameters (`m/2`, `(oh-1)*stride+kh`, …) directly.
//! * Only *dynamic indices* — slice starts that depend on a schedule's loop
//!   variable — are child expressions (`Int` / `LVar` / `IMul` / `IAdd`).
//! * Schedules bind **named** loop variables ([`Op::SchedLoop`] etc. carry a
//!   [`Symbol`]); rewrites always bind fresh names, so there is no capture
//!   and no de Bruijn shifting inside the e-graph.
//! * Everything *about* an op other than its identity — arity, attribute
//!   schema, shape rule, reference kernel, lowering template, cost model —
//!   lives in the op's [`crate::ir::spec::OpSpec`] registry entry. Adding an
//!   operator means adding the variant here (plus its [`Op::kind`] arm) and
//!   one registry entry; no other match site in the crate grows an arm.

use super::shape::Shape;
use super::symbol::Symbol;
use std::fmt;
use std::sync::Arc;

/// Inline constant tensor data: a static shape plus its `f32` values,
/// stored as raw bit patterns (`u32`) so the payload is `Eq`/`Hash`-exact
/// (e-graph hashconsing interns identical constants structurally, like
/// engine declarations). The content hash is precomputed once at
/// construction — e-nodes carrying megabyte weights hash in O(1).
#[derive(Clone)]
pub struct ConstData {
    shape: Shape,
    bits: Arc<Vec<u32>>,
    hash: u64,
}

impl ConstData {
    pub fn new(shape: Shape, values: &[f32]) -> Self {
        assert_eq!(shape.numel(), values.len(), "const shape/data mismatch");
        let bits: Vec<u32> = values.iter().map(|v| v.to_bits()).collect();
        use std::hash::{Hash, Hasher};
        let mut h = crate::fx::FxHasher::default();
        shape.hash(&mut h);
        bits.hash(&mut h);
        ConstData { shape, bits: Arc::new(bits), hash: h.finish() }
    }

    pub fn shape(&self) -> &Shape {
        &self.shape
    }

    /// The stored values, decoded back to `f32`.
    pub fn values(&self) -> Vec<f32> {
        self.bits.iter().map(|&b| f32::from_bits(b)).collect()
    }

    /// Raw bit patterns (exact-roundtrip persistence uses these).
    pub fn bits(&self) -> &[u32] {
        &self.bits
    }

    /// The precomputed content hash.
    pub fn content_hash(&self) -> u64 {
        self.hash
    }
}

impl PartialEq for ConstData {
    fn eq(&self, other: &Self) -> bool {
        self.hash == other.hash && self.shape == other.shape && self.bits == other.bits
    }
}

impl Eq for ConstData {}

impl std::hash::Hash for ConstData {
    fn hash<H: std::hash::Hasher>(&self, state: &mut H) {
        state.write_u64(self.hash);
    }
}

impl fmt::Debug for ConstData {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // Content hash, not values: Debug feeds e-graph dumps and structural
        // fingerprints, where a megabyte literal would be noise.
        write!(f, "ConstData{}#{:016x}", self.shape, self.hash)
    }
}

/// Storage kind for explicit buffer materialization points.
#[derive(Copy, Clone, PartialEq, Eq, Hash, Debug, PartialOrd, Ord)]
pub enum BufKind {
    /// On-chip scratchpad (VMEM/BRAM-class): fast, area-costly.
    Sram,
    /// Off-chip memory (HBM/DRAM-class): free area, slow.
    Dram,
}

impl BufKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            BufKind::Sram => "sram",
            BufKind::Dram => "dram",
        }
    }
}

/// An EngineIR operator. See the module docs for the sub-language split
/// (index scalars / Relay ops / engines / invocations / schedules / storage).
#[derive(Clone, PartialEq, Eq, Hash, Debug)]
pub enum Op {
    // ------------------------------------------------------------------
    // Index scalars (children of `SliceAx` starts only)
    // ------------------------------------------------------------------
    /// Integer literal.
    Int(i64),
    /// Reference to an enclosing schedule's loop variable.
    LVar(Symbol),
    /// Integer multiply; children `[a, b]`.
    IMul,
    /// Integer add; children `[a, b]`.
    IAdd,

    // ------------------------------------------------------------------
    // Workload tensors (leaves)
    // ------------------------------------------------------------------
    /// Named workload input with static shape.
    Input(Symbol, Shape),
    /// Named trained parameter with static shape.
    Weight(Symbol, Shape),

    // ------------------------------------------------------------------
    // Relay-level operators (pre-reification; N=1 inference, CHW layout)
    // ------------------------------------------------------------------
    /// 2-D convolution; children `[x:(C,H,W), w:(K,C,KH,KW)]` (KH and KW
    /// may differ — kernels are rectangular). `pad_h`/`pad_w` are the
    /// **total** zero padding added to H and W respectively, split
    /// `floor(p/2)` before / `ceil(p/2)` after (ONNX `SAME_UPPER`), so odd
    /// totals — e.g. SAME padding for a stride-2 3×3 kernel — are exact.
    /// The old symmetric `pad: p` is `pad_h = pad_w = 2p`.
    Conv2d { stride: usize, pad_h: usize, pad_w: usize },
    /// Dense / fully-connected; children `[x:(M,K), w:(K,N)]`.
    Dense,
    /// Elementwise ReLU; children `[x]` (any shape).
    Relu,
    /// Bias add; children `[x, b]`, `b` broadcast along `x`'s leading dim
    /// (rank-3 `x`) or trailing dim (rank-2 `x`).
    BiasAdd,
    /// Elementwise add; children `[x, y]` (same shape).
    EAdd,
    /// Max pooling; children `[x:(C,H,W)]` (rectangular `kh`×`kw` window).
    MaxPool2d { kh: usize, kw: usize, stride: usize },
    /// Flatten to `(1, numel)`; children `[x]`.
    Flatten,
    /// Global average pool `(C,H,W) -> (C)`; children `[x]`.
    GlobalAvgPool,
    /// General matrix multiply of two *computed* tensors (unlike [`Op::Dense`]
    /// both operands are usually activations); children `[a:(M,K), b:(K,N)]`.
    Matmul,
    /// Batched matmul; children `[a:(B,M,K), b:(B,K,N)] -> (B,M,N)`.
    BatchMatmul,
    /// Row-wise softmax over the last axis; children `[x]` (rank 1, 2 or 3;
    /// leading axes are independent rows).
    Softmax,
    /// Affine layer normalization over the last axis (ε=1e-5):
    /// `gamma ⊙ norm(x) + beta`; children `[x, gamma, beta]` with `x` of
    /// rank 1 or 2 and `gamma`/`beta` rank 1 of the last-axis length.
    LayerNorm,
    /// Elementwise multiply (Hadamard product); children `[x, y]` (same
    /// shape). The scale half of affine layernorm, and the op the
    /// `emul-engine` reifies.
    Emul,
    /// Elementwise GELU (tanh approximation); children `[x]` (any shape).
    Gelu,
    /// Depthwise 2-D convolution (channel multiplier 1); children
    /// `[x:(C,H,W), w:(C,KH,KW)]`. Padding semantics as [`Op::Conv2d`]:
    /// total per dimension, SAME_UPPER split.
    DepthwiseConv2d { stride: usize, pad_h: usize, pad_w: usize },
    /// Inline constant tensor (imported model weights, attention scale
    /// vectors): a leaf carrying its data, content-hashed for interning.
    Constant(ConstData),

    // ------------------------------------------------------------------
    // Hardware engine declarations (leaves; paper Fig. 1)
    // ------------------------------------------------------------------
    /// Matrix-multiply engine computing `(m,k) @ (k,n)`.
    MmEngine { m: usize, k: usize, n: usize },
    /// Fused matmul+ReLU engine (extension rewrite R7).
    MmReluEngine { m: usize, k: usize, n: usize },
    /// `w`-wide vector ReLU unit (paper Fig. 2).
    ReluEngine { w: usize },
    /// `w`-wide vector adder.
    AddEngine { w: usize },
    /// Direct convolution engine producing a `(k, oh, ow)` output tile from
    /// a `(c, ih, iw)` input tile with a rectangular `kh`×`kw` kernel
    /// (paper Fig. 1's `conv_engine<H, W, C, K>`, generalized).
    ConvEngine { oh: usize, ow: usize, c: usize, k: usize, kh: usize, kw: usize, stride: usize },
    /// Max-pool engine producing `(c, oh, ow)` from `(c, ih, iw)` with a
    /// rectangular `kh`×`kw` window (square pooling is the `kh == kw` case).
    PoolEngine { oh: usize, ow: usize, c: usize, kh: usize, kw: usize, stride: usize },
    /// `w`-wide row softmax unit (normalization is coupled across the row,
    /// so this engine does not split along `w`).
    SoftmaxEngine { w: usize },
    /// `w`-wide row layer-normalization unit (same coupling as softmax).
    LayerNormEngine { w: usize },
    /// `w`-wide vector GELU unit.
    GeluEngine { w: usize },
    /// `w`-wide vector elementwise-multiply unit (the `add-engine`'s
    /// multiplicative sibling; carries affine layernorm's gamma scale).
    EmulEngine { w: usize },
    /// Depthwise convolution engine producing `(c, oh, ow)` from a
    /// `(c, ih, iw)` tile with a per-channel `kh`×`kw` kernel.
    DwConvEngine { oh: usize, ow: usize, c: usize, kh: usize, kw: usize, stride: usize },

    // ------------------------------------------------------------------
    // Engine invocations: children `[engine, tensor args...]`
    // ------------------------------------------------------------------
    /// `[e:MmEngine, a:(m,k), b:(k,n)] -> (m,n)`.
    InvokeMm,
    /// `[e:MmReluEngine, a, b] -> relu(a@b)`.
    InvokeMmRelu,
    /// `[e:ReluEngine, x:(w,)] -> (w,)`.
    InvokeRelu,
    /// `[e:AddEngine, x:(w,), y:(w,)] -> (w,)`.
    InvokeAdd,
    /// `[e:ConvEngine, x:(c,ih,iw), w:(k,c,kh,kw)] -> (k,oh,ow)`.
    InvokeConv,
    /// `[e:PoolEngine, x:(c,ih,iw)] -> (c,oh,ow)`.
    InvokePool,
    /// `[e:SoftmaxEngine, x:(w,)] -> (w,)`.
    InvokeSoftmax,
    /// `[e:LayerNormEngine, x:(w,)] -> (w,)`.
    InvokeLayerNorm,
    /// `[e:GeluEngine, x:(w,)] -> (w,)`.
    InvokeGelu,
    /// `[e:DwConvEngine, x:(c,ih,iw), w:(c,kh,kw)] -> (c,oh,ow)`.
    InvokeDwConv,
    /// `[e:EmulEngine, x:(w,), y:(w,)] -> (w,)`.
    InvokeEmul,

    // ------------------------------------------------------------------
    // Software schedules: children `[body]`
    // ------------------------------------------------------------------
    /// Sequential loop: run `body` `extent` times (binding `var` to
    /// `0..extent`), concatenating results along `axis`. One engine
    /// instance, time-multiplexed — paper Fig. 2 rewrite 1.
    SchedLoop { var: Symbol, axis: usize, extent: usize },
    /// Parallel map: same semantics as `SchedLoop`, but `extent` hardware
    /// instances run concurrently — paper Fig. 2 rewrite 2.
    SchedPar { var: Symbol, axis: usize, extent: usize },
    /// Reduction schedule: run `body` `extent` times and sum the results
    /// elementwise (used by matmul K-splitting).
    SchedReduce { var: Symbol, extent: usize },

    // ------------------------------------------------------------------
    // Data movement & storage
    // ------------------------------------------------------------------
    /// Slice `len` elements along `axis`; children `[start:index, x]`.
    SliceAx { axis: usize, len: usize },
    /// Reshape to a static shape; children `[x]`.
    Reshape(Shape),
    /// Broadcast a 1-D tensor to `shape` along dim 0 (rank-3 result) or
    /// dim 1 (rank-2 result); children `[b]`.
    Bcast(Shape),
    /// Zero-pad H and W of a `(C,H,W)` tensor; children `[x]`. `pad_h` /
    /// `pad_w` are **total** padding per dimension, split `floor(p/2)`
    /// before / `ceil(p/2)` after (SAME_UPPER — see [`Op::Conv2d`]).
    Pad2d { pad_h: usize, pad_w: usize },
    /// im2col: `(c,ih,iw) -> (c*kh*kw, oh*ow)` patch matrix; children `[x]`.
    Im2Col { kh: usize, kw: usize, stride: usize },
    /// Transpose of the trailing two axes: `(m,n) -> (n,m)` for rank 2,
    /// `(b,m,n) -> (b,n,m)` for rank 3 (the batched form multi-head
    /// attention uses to pack per-head operands); children `[x]`.
    Transpose,
    /// Materialize the child into an explicit storage buffer.
    Buffer { kind: BufKind },
    /// Double-buffered materialization (pipelining rewrite R6).
    DblBuffer { kind: BufKind },
}

/// Coarse operator classification used by pattern matching ([`OpKind`]
/// matchers bind any op of a kind), by the [`crate::ir::spec`] registry
/// (one [`crate::ir::spec::OpSpec`] per kind, indexed by discriminant), and
/// by cost/statistics code.
///
/// Declaration order is the registry index: [`OpKind::ALL`] and the spec
/// table in `ir::spec` list kinds in exactly this order (checked at
/// registry construction).
#[derive(Copy, Clone, PartialEq, Eq, Hash, Debug)]
pub enum OpKind {
    Int,
    LVar,
    IMul,
    IAdd,
    Input,
    Weight,
    Conv2d,
    Dense,
    Relu,
    BiasAdd,
    EAdd,
    MaxPool2d,
    Flatten,
    GlobalAvgPool,
    MmEngine,
    MmReluEngine,
    ReluEngine,
    AddEngine,
    ConvEngine,
    PoolEngine,
    InvokeMm,
    InvokeMmRelu,
    InvokeRelu,
    InvokeAdd,
    InvokeConv,
    InvokePool,
    SchedLoop,
    SchedPar,
    SchedReduce,
    SliceAx,
    Reshape,
    Bcast,
    Pad2d,
    Im2Col,
    Buffer,
    DblBuffer,
    Matmul,
    BatchMatmul,
    Transpose,
    Softmax,
    LayerNorm,
    Gelu,
    DepthwiseConv2d,
    SoftmaxEngine,
    LayerNormEngine,
    GeluEngine,
    DwConvEngine,
    InvokeSoftmax,
    InvokeLayerNorm,
    InvokeGelu,
    InvokeDwConv,
    Emul,
    EmulEngine,
    InvokeEmul,
    Constant,
}

impl OpKind {
    /// Every kind, in declaration (= registry) order. Kept in sync with the
    /// enum by the registry constructor, which asserts
    /// `ALL[i] as usize == i` for every entry.
    pub const ALL: &'static [OpKind] = &[
        OpKind::Int,
        OpKind::LVar,
        OpKind::IMul,
        OpKind::IAdd,
        OpKind::Input,
        OpKind::Weight,
        OpKind::Conv2d,
        OpKind::Dense,
        OpKind::Relu,
        OpKind::BiasAdd,
        OpKind::EAdd,
        OpKind::MaxPool2d,
        OpKind::Flatten,
        OpKind::GlobalAvgPool,
        OpKind::MmEngine,
        OpKind::MmReluEngine,
        OpKind::ReluEngine,
        OpKind::AddEngine,
        OpKind::ConvEngine,
        OpKind::PoolEngine,
        OpKind::InvokeMm,
        OpKind::InvokeMmRelu,
        OpKind::InvokeRelu,
        OpKind::InvokeAdd,
        OpKind::InvokeConv,
        OpKind::InvokePool,
        OpKind::SchedLoop,
        OpKind::SchedPar,
        OpKind::SchedReduce,
        OpKind::SliceAx,
        OpKind::Reshape,
        OpKind::Bcast,
        OpKind::Pad2d,
        OpKind::Im2Col,
        OpKind::Buffer,
        OpKind::DblBuffer,
        OpKind::Matmul,
        OpKind::BatchMatmul,
        OpKind::Transpose,
        OpKind::Softmax,
        OpKind::LayerNorm,
        OpKind::Gelu,
        OpKind::DepthwiseConv2d,
        OpKind::SoftmaxEngine,
        OpKind::LayerNormEngine,
        OpKind::GeluEngine,
        OpKind::DwConvEngine,
        OpKind::InvokeSoftmax,
        OpKind::InvokeLayerNorm,
        OpKind::InvokeGelu,
        OpKind::InvokeDwConv,
        OpKind::Emul,
        OpKind::EmulEngine,
        OpKind::InvokeEmul,
        OpKind::Constant,
    ];

    /// This kind's registry entry.
    pub fn spec(self) -> &'static super::spec::OpSpec {
        super::spec::of(self)
    }
}

impl Op {
    /// The coarse kind of this op.
    pub fn kind(&self) -> OpKind {
        match self {
            Op::Int(_) => OpKind::Int,
            Op::LVar(_) => OpKind::LVar,
            Op::IMul => OpKind::IMul,
            Op::IAdd => OpKind::IAdd,
            Op::Input(..) => OpKind::Input,
            Op::Weight(..) => OpKind::Weight,
            Op::Constant(_) => OpKind::Constant,
            Op::Conv2d { .. } => OpKind::Conv2d,
            Op::Dense => OpKind::Dense,
            Op::Relu => OpKind::Relu,
            Op::BiasAdd => OpKind::BiasAdd,
            Op::EAdd => OpKind::EAdd,
            Op::MaxPool2d { .. } => OpKind::MaxPool2d,
            Op::Flatten => OpKind::Flatten,
            Op::GlobalAvgPool => OpKind::GlobalAvgPool,
            Op::Matmul => OpKind::Matmul,
            Op::BatchMatmul => OpKind::BatchMatmul,
            Op::Softmax => OpKind::Softmax,
            Op::LayerNorm => OpKind::LayerNorm,
            Op::Emul => OpKind::Emul,
            Op::Gelu => OpKind::Gelu,
            Op::DepthwiseConv2d { .. } => OpKind::DepthwiseConv2d,
            Op::MmEngine { .. } => OpKind::MmEngine,
            Op::MmReluEngine { .. } => OpKind::MmReluEngine,
            Op::ReluEngine { .. } => OpKind::ReluEngine,
            Op::AddEngine { .. } => OpKind::AddEngine,
            Op::ConvEngine { .. } => OpKind::ConvEngine,
            Op::PoolEngine { .. } => OpKind::PoolEngine,
            Op::SoftmaxEngine { .. } => OpKind::SoftmaxEngine,
            Op::LayerNormEngine { .. } => OpKind::LayerNormEngine,
            Op::GeluEngine { .. } => OpKind::GeluEngine,
            Op::EmulEngine { .. } => OpKind::EmulEngine,
            Op::DwConvEngine { .. } => OpKind::DwConvEngine,
            Op::InvokeMm => OpKind::InvokeMm,
            Op::InvokeMmRelu => OpKind::InvokeMmRelu,
            Op::InvokeRelu => OpKind::InvokeRelu,
            Op::InvokeAdd => OpKind::InvokeAdd,
            Op::InvokeConv => OpKind::InvokeConv,
            Op::InvokePool => OpKind::InvokePool,
            Op::InvokeSoftmax => OpKind::InvokeSoftmax,
            Op::InvokeLayerNorm => OpKind::InvokeLayerNorm,
            Op::InvokeGelu => OpKind::InvokeGelu,
            Op::InvokeDwConv => OpKind::InvokeDwConv,
            Op::InvokeEmul => OpKind::InvokeEmul,
            Op::SchedLoop { .. } => OpKind::SchedLoop,
            Op::SchedPar { .. } => OpKind::SchedPar,
            Op::SchedReduce { .. } => OpKind::SchedReduce,
            Op::SliceAx { .. } => OpKind::SliceAx,
            Op::Reshape(_) => OpKind::Reshape,
            Op::Bcast(_) => OpKind::Bcast,
            Op::Pad2d { .. } => OpKind::Pad2d,
            Op::Im2Col { .. } => OpKind::Im2Col,
            Op::Transpose => OpKind::Transpose,
            Op::Buffer { .. } => OpKind::Buffer,
            Op::DblBuffer { .. } => OpKind::DblBuffer,
        }
    }

    /// This op's registry entry.
    pub fn spec(&self) -> &'static super::spec::OpSpec {
        super::spec::of(self.kind())
    }

    /// This op's registry class.
    pub fn class(&self) -> super::spec::OpClass {
        self.spec().class
    }

    /// Number of children this op expects, if fixed (all EngineIR ops have
    /// fixed arity; this is `None` only for future variadic ops).
    pub fn arity(&self) -> Option<usize> {
        Some(self.spec().arity)
    }

    /// True for hardware engine declarations.
    pub fn is_engine(&self) -> bool {
        matches!(self.class(), super::spec::OpClass::Engine)
    }

    /// True for engine invocations.
    pub fn is_invoke(&self) -> bool {
        matches!(self.class(), super::spec::OpClass::Invoke)
    }

    /// True for software schedule combinators.
    pub fn is_sched(&self) -> bool {
        matches!(self.class(), super::spec::OpClass::Sched)
    }

    /// True for Relay-level (unreified) operators.
    pub fn is_relay(&self) -> bool {
        matches!(self.class(), super::spec::OpClass::Relay)
    }

    /// Multiply–accumulate count of one invocation of an engine declaration
    /// (0 for non-engines). The basis of the area and latency models.
    pub fn engine_macs(&self) -> u64 {
        match self.spec().engine {
            Some(e) => (e.macs)(self),
            None => 0,
        }
    }
}

impl fmt::Display for Op {
    /// Human-readable head form, derived from the registry: leaves print
    /// their full s-expression (`(mm-engine 16 16 16)`), non-leaf ops print
    /// `head[labeled,attrs]` (`conv2d[s1,ph2,pw2]`, `sched-loop[i0,a0,x2]`).
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if let Op::Int(v) = self {
            return write!(f, "{v}");
        }
        let spec = self.spec();
        let attrs = (spec.attrs_of)(self);
        if spec.arity == 0 {
            write!(f, "({}", spec.name)?;
            for a in &attrs {
                write!(f, " {}", a.sexpr())?;
            }
            write!(f, ")")
        } else if attrs.is_empty() {
            write!(f, "{}", spec.name)
        } else {
            write!(f, "{}[", spec.name)?;
            for (i, (a, (label, _))) in attrs.iter().zip(spec.attrs.iter()).enumerate() {
                if i > 0 {
                    write!(f, ",")?;
                }
                write!(f, "{label}{}", a.compact())?;
            }
            write!(f, "]")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arity_matches_docs() {
        assert_eq!(Op::InvokeMm.arity(), Some(3));
        assert_eq!(Op::Relu.arity(), Some(1));
        assert_eq!(Op::MmEngine { m: 4, k: 4, n: 4 }.arity(), Some(0));
        assert_eq!(Op::SliceAx { axis: 0, len: 4 }.arity(), Some(2));
        assert_eq!(Op::Matmul.arity(), Some(2));
        assert_eq!(Op::InvokeDwConv.arity(), Some(3));
        assert_eq!(Op::Emul.arity(), Some(2));
        assert_eq!(Op::InvokeEmul.arity(), Some(3));
        // Affine layernorm takes gamma and beta operands.
        assert_eq!(Op::LayerNorm.arity(), Some(3));
    }

    #[test]
    fn engine_classification() {
        assert!(Op::ReluEngine { w: 8 }.is_engine());
        assert!(Op::SoftmaxEngine { w: 8 }.is_engine());
        assert!(Op::EmulEngine { w: 8 }.is_engine());
        assert!(Op::InvokeEmul.is_invoke());
        assert!(Op::Emul.is_relay());
        assert!(!Op::InvokeRelu.is_engine());
        assert!(Op::InvokeRelu.is_invoke());
        assert!(Op::InvokeGelu.is_invoke());
        assert!(Op::SchedLoop { var: Symbol::new("i"), axis: 0, extent: 2 }.is_sched());
        assert!(Op::Dense.is_relay());
        assert!(Op::Softmax.is_relay());
        // Transpose is data movement, not host compute.
        assert!(!Op::Transpose.is_relay());
    }

    #[test]
    fn engine_macs_scale_with_params() {
        let small = Op::MmEngine { m: 4, k: 4, n: 4 }.engine_macs();
        let big = Op::MmEngine { m: 8, k: 4, n: 4 }.engine_macs();
        assert_eq!(big, 2 * small);
        assert_eq!(Op::ReluEngine { w: 128 }.engine_macs(), 128);
        // Rectangular conv engine: macs scale with kh*kw.
        let sq = Op::ConvEngine { oh: 2, ow: 2, c: 1, k: 1, kh: 3, kw: 3, stride: 1 };
        let rect = Op::ConvEngine { oh: 2, ow: 2, c: 1, k: 1, kh: 3, kw: 1, stride: 1 };
        assert_eq!(sq.engine_macs(), 3 * rect.engine_macs());
    }

    #[test]
    fn ops_hash_structurally() {
        use std::collections::HashSet;
        let mut s = HashSet::new();
        s.insert(Op::MmEngine { m: 16, k: 16, n: 16 });
        // Same parameters -> same engine declaration -> shared hardware.
        assert!(s.contains(&Op::MmEngine { m: 16, k: 16, n: 16 }));
        assert!(!s.contains(&Op::MmEngine { m: 16, k: 16, n: 8 }));
    }

    #[test]
    fn display_head_forms() {
        assert_eq!(
            Op::Conv2d { stride: 1, pad_h: 2, pad_w: 2 }.to_string(),
            "conv2d[s1,ph2,pw2]"
        );
        assert_eq!(
            Op::DepthwiseConv2d { stride: 2, pad_h: 1, pad_w: 1 }.to_string(),
            "dwconv2d[s2,ph1,pw1]"
        );
        assert_eq!(
            Op::SchedLoop { var: Symbol::new("i0"), axis: 0, extent: 2 }.to_string(),
            "sched-loop[i0,a0,x2]"
        );
        // Shape attrs drop their own brackets in the head form.
        assert_eq!(Op::Reshape(Shape::new(&[2, 2])).to_string(), "reshape[2,2]");
        // Leaves print their full s-expression.
        assert_eq!(Op::MmEngine { m: 4, k: 8, n: 2 }.to_string(), "(mm-engine 4 8 2)");
        assert_eq!(Op::Int(7).to_string(), "7");
        assert_eq!(Op::Dense.to_string(), "dense");
    }

    #[test]
    fn constants_intern_by_content() {
        use std::collections::HashSet;
        let c = |vals: &[f32]| Op::Constant(ConstData::new(Shape::new(&[vals.len()]), vals));
        let mut s = HashSet::new();
        s.insert(c(&[1.0, 2.0]));
        // Same shape + same bits -> same e-node -> hashcons sharing.
        assert!(s.contains(&c(&[1.0, 2.0])));
        assert!(!s.contains(&c(&[1.0, 2.5])));
        // -0.0 and 0.0 differ bitwise: constants are bit-exact, not
        // numerically fuzzy (float Eq through bit patterns is total).
        assert_ne!(
            ConstData::new(Shape::new(&[1]), &[0.0]),
            ConstData::new(Shape::new(&[1]), &[-0.0])
        );
        let a = ConstData::new(Shape::new(&[2]), &[3.0, -0.5]);
        let b = ConstData::new(Shape::new(&[2]), &[3.0, -0.5]);
        assert_eq!(a.content_hash(), b.content_hash());
        assert_eq!(a.values(), vec![3.0, -0.5]);
        assert!(Op::Constant(a).arity() == Some(0));
    }

    #[test]
    fn opkind_all_is_registry_order() {
        for (i, &k) in OpKind::ALL.iter().enumerate() {
            assert_eq!(k as usize, i, "{k:?} out of registry order");
        }
    }
}
