//! S-expression printer for EngineIR. The grammar is the exact inverse of
//! [`super::parse`]; `parse(print(e))` round-trips (tested there, and per
//! op in `tests/registry.rs`).
//!
//! The printer is fully registry-driven: every op renders as
//! `(head attrs... children...)` using its [`crate::ir::spec::OpSpec`]'s
//! head name and attribute extractor. Only the bare integer literal is
//! special-cased. Adding an op requires no change here.

use super::op::Op;
use super::recexpr::RecExpr;
use crate::egraph::Id;
use std::fmt::Write;

/// Render the subtree of `expr` rooted at `id` as an s-expression.
/// Shared subtrees are printed in full at each use (the *term*, not the DAG).
pub fn to_sexpr(expr: &RecExpr, id: Id) -> String {
    let mut s = String::new();
    write_sexpr(expr, id, &mut s);
    s
}

fn write_sexpr(expr: &RecExpr, id: Id, out: &mut String) {
    let node = expr.node(id);
    if let Op::Int(v) = &node.op {
        write!(out, "{v}").unwrap();
        return;
    }
    let spec = node.op.spec();
    write!(out, "({}", spec.name).unwrap();
    for attr in (spec.attrs_of)(&node.op) {
        out.push(' ');
        out.push_str(&attr.sexpr());
    }
    for &c in &node.children {
        out.push(' ');
        write_sexpr(expr, c, out);
    }
    out.push(')');
}

/// Indented multi-line pretty printer (for CLI / example output).
pub fn pretty(expr: &RecExpr) -> String {
    let mut out = String::new();
    pretty_rec(expr, expr.root(), 0, &mut out);
    out
}

fn pretty_rec(expr: &RecExpr, id: Id, indent: usize, out: &mut String) {
    let node = expr.node(id);
    let pad = "  ".repeat(indent);
    if node.children.is_empty() {
        let _ = writeln!(out, "{pad}{}", to_sexpr(expr, id));
        return;
    }
    // Short subtrees stay on one line.
    let flat = to_sexpr(expr, id);
    if flat.len() <= 72 {
        let _ = writeln!(out, "{pad}{flat}");
        return;
    }
    // Head: the op's Display form (head symbol + bracketed attrs).
    let _ = writeln!(out, "{pad}({}", node.op);
    for &c in &node.children {
        pretty_rec(expr, c, indent + 1, out);
    }
    let _ = writeln!(out, "{pad})");
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{Shape, Symbol};

    #[test]
    fn prints_fig2_initial_program() {
        let mut e = RecExpr::new();
        let x = e.add_leaf(Op::Input(Symbol::new("x"), Shape::new(&[128])));
        let eng = e.add_leaf(Op::ReluEngine { w: 128 });
        e.add_op(Op::InvokeRelu, &[eng, x]);
        assert_eq!(e.to_string(), "(invoke-relu (relu-engine 128) (input x [128]))");
    }

    #[test]
    fn prints_schedule() {
        let mut e = RecExpr::new();
        let x = e.add_leaf(Op::Input(Symbol::new("x"), Shape::new(&[128])));
        let i = e.add_leaf(Op::LVar(Symbol::new("i0")));
        let w = e.add_leaf(Op::Int(64));
        let start = e.add_op(Op::IMul, &[i, w]);
        let sl = e.add_op(Op::SliceAx { axis: 0, len: 64 }, &[start, x]);
        let eng = e.add_leaf(Op::ReluEngine { w: 64 });
        let inv = e.add_op(Op::InvokeRelu, &[eng, sl]);
        e.add_op(Op::SchedLoop { var: Symbol::new("i0"), axis: 0, extent: 2 }, &[inv]);
        let s = e.to_string();
        assert!(s.starts_with("(sched-loop i0 0 2 "), "{s}");
        assert!(s.contains("(slice 0 64 (imul (lvar i0) 64)"), "{s}");
    }

    #[test]
    fn prints_new_ops_via_registry() {
        let mut e = RecExpr::new();
        let x = e.add_leaf(Op::Input(Symbol::new("x"), Shape::new(&[4, 8])));
        let t = e.add_op(Op::Transpose, &[x]);
        e.add_op(Op::Softmax, &[t]);
        assert_eq!(e.to_string(), "(softmax (transpose (input x [4 8])))");
    }
}
