//! S-expression printer for EngineIR. The grammar is the exact inverse of
//! [`super::parse`]; `parse(print(e))` round-trips (tested there).

use super::op::Op;
use super::recexpr::RecExpr;
use super::shape::Shape;
use crate::egraph::Id;
use std::fmt::Write;

fn shape_str(s: &Shape) -> String {
    let dims: Vec<String> = s.0.iter().map(|d| d.to_string()).collect();
    format!("[{}]", dims.join(" "))
}

/// Render the subtree of `expr` rooted at `id` as an s-expression.
/// Shared subtrees are printed in full at each use (the *term*, not the DAG).
pub fn to_sexpr(expr: &RecExpr, id: Id) -> String {
    let mut s = String::new();
    write_sexpr(expr, id, &mut s);
    s
}

fn write_sexpr(expr: &RecExpr, id: Id, out: &mut String) {
    let node = expr.node(id);
    let kids = |out: &mut String, e: &RecExpr| {
        for &c in &node.children {
            out.push(' ');
            write_sexpr(e, c, out);
        }
    };
    match &node.op {
        Op::Int(v) => {
            write!(out, "{v}").unwrap();
        }
        Op::LVar(s) => {
            write!(out, "(lvar {s})").unwrap();
        }
        Op::IMul => {
            out.push_str("(imul");
            kids(out, expr);
            out.push(')');
        }
        Op::IAdd => {
            out.push_str("(iadd");
            kids(out, expr);
            out.push(')');
        }
        Op::Input(name, sh) => {
            write!(out, "(input {name} {})", shape_str(sh)).unwrap();
        }
        Op::Weight(name, sh) => {
            write!(out, "(weight {name} {})", shape_str(sh)).unwrap();
        }
        Op::Conv2d { stride, pad } => {
            write!(out, "(conv2d {stride} {pad}").unwrap();
            kids(out, expr);
            out.push(')');
        }
        Op::Dense => {
            out.push_str("(dense");
            kids(out, expr);
            out.push(')');
        }
        Op::Relu => {
            out.push_str("(relu");
            kids(out, expr);
            out.push(')');
        }
        Op::BiasAdd => {
            out.push_str("(bias-add");
            kids(out, expr);
            out.push(')');
        }
        Op::EAdd => {
            out.push_str("(eadd");
            kids(out, expr);
            out.push(')');
        }
        Op::MaxPool2d { k, stride } => {
            write!(out, "(maxpool2d {k} {stride}").unwrap();
            kids(out, expr);
            out.push(')');
        }
        Op::Flatten => {
            out.push_str("(flatten");
            kids(out, expr);
            out.push(')');
        }
        Op::GlobalAvgPool => {
            out.push_str("(gap");
            kids(out, expr);
            out.push(')');
        }
        Op::MmEngine { m, k, n } => {
            write!(out, "(mm-engine {m} {k} {n})").unwrap();
        }
        Op::MmReluEngine { m, k, n } => {
            write!(out, "(mm-relu-engine {m} {k} {n})").unwrap();
        }
        Op::ReluEngine { w } => {
            write!(out, "(relu-engine {w})").unwrap();
        }
        Op::AddEngine { w } => {
            write!(out, "(add-engine {w})").unwrap();
        }
        Op::ConvEngine { oh, ow, c, k, kh, stride } => {
            write!(out, "(conv-engine {oh} {ow} {c} {k} {kh} {stride})").unwrap();
        }
        Op::PoolEngine { oh, ow, c, k, stride } => {
            write!(out, "(pool-engine {oh} {ow} {c} {k} {stride})").unwrap();
        }
        Op::InvokeMm => {
            out.push_str("(invoke-mm");
            kids(out, expr);
            out.push(')');
        }
        Op::InvokeMmRelu => {
            out.push_str("(invoke-mm-relu");
            kids(out, expr);
            out.push(')');
        }
        Op::InvokeRelu => {
            out.push_str("(invoke-relu");
            kids(out, expr);
            out.push(')');
        }
        Op::InvokeAdd => {
            out.push_str("(invoke-add");
            kids(out, expr);
            out.push(')');
        }
        Op::InvokeConv => {
            out.push_str("(invoke-conv");
            kids(out, expr);
            out.push(')');
        }
        Op::InvokePool => {
            out.push_str("(invoke-pool");
            kids(out, expr);
            out.push(')');
        }
        Op::SchedLoop { var, axis, extent } => {
            write!(out, "(sched-loop {var} {axis} {extent}").unwrap();
            kids(out, expr);
            out.push(')');
        }
        Op::SchedPar { var, axis, extent } => {
            write!(out, "(sched-par {var} {axis} {extent}").unwrap();
            kids(out, expr);
            out.push(')');
        }
        Op::SchedReduce { var, extent } => {
            write!(out, "(sched-reduce {var} {extent}").unwrap();
            kids(out, expr);
            out.push(')');
        }
        Op::SliceAx { axis, len } => {
            write!(out, "(slice {axis} {len}").unwrap();
            kids(out, expr);
            out.push(')');
        }
        Op::Reshape(sh) => {
            write!(out, "(reshape {}", shape_str(sh)).unwrap();
            kids(out, expr);
            out.push(')');
        }
        Op::Bcast(sh) => {
            write!(out, "(bcast {}", shape_str(sh)).unwrap();
            kids(out, expr);
            out.push(')');
        }
        Op::Pad2d { pad } => {
            write!(out, "(pad2d {pad}").unwrap();
            kids(out, expr);
            out.push(')');
        }
        Op::Im2Col { kh, stride } => {
            write!(out, "(im2col {kh} {stride}").unwrap();
            kids(out, expr);
            out.push(')');
        }
        Op::Buffer { kind } => {
            write!(out, "(buffer {}", kind.as_str()).unwrap();
            kids(out, expr);
            out.push(')');
        }
        Op::DblBuffer { kind } => {
            write!(out, "(dbl-buffer {}", kind.as_str()).unwrap();
            kids(out, expr);
            out.push(')');
        }
    }
}

/// Indented multi-line pretty printer (for CLI / example output).
pub fn pretty(expr: &RecExpr) -> String {
    let mut out = String::new();
    pretty_rec(expr, expr.root(), 0, &mut out);
    out
}

fn pretty_rec(expr: &RecExpr, id: Id, indent: usize, out: &mut String) {
    let node = expr.node(id);
    let pad = "  ".repeat(indent);
    if node.children.is_empty() {
        let _ = writeln!(out, "{pad}{}", to_sexpr(expr, id));
        return;
    }
    // Short subtrees stay on one line.
    let flat = to_sexpr(expr, id);
    if flat.len() <= 72 {
        let _ = writeln!(out, "{pad}{flat}");
        return;
    }
    let head = {
        // Everything before the first child in the flat form.
        let mut tmp = RecExpr::new();
        let hollow = super::recexpr::Node::new(node.op.clone(), vec![]);
        // Print just the head symbol by formatting a leaf-ified copy when
        // the op is structurally a leaf; otherwise synthesize from Display.
        if node.op.arity() == Some(0) {
            tmp.add(hollow);
            to_sexpr(&tmp, tmp.root())
        } else {
            format!("({}", node.op)
        }
    };
    let _ = writeln!(out, "{pad}{head}");
    for &c in &node.children {
        pretty_rec(expr, c, indent + 1, out);
    }
    let _ = writeln!(out, "{pad})");
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{Shape, Symbol};

    #[test]
    fn prints_fig2_initial_program() {
        let mut e = RecExpr::new();
        let x = e.add_leaf(Op::Input(Symbol::new("x"), Shape::new(&[128])));
        let eng = e.add_leaf(Op::ReluEngine { w: 128 });
        e.add_op(Op::InvokeRelu, &[eng, x]);
        assert_eq!(e.to_string(), "(invoke-relu (relu-engine 128) (input x [128]))");
    }

    #[test]
    fn prints_schedule() {
        let mut e = RecExpr::new();
        let x = e.add_leaf(Op::Input(Symbol::new("x"), Shape::new(&[128])));
        let i = e.add_leaf(Op::LVar(Symbol::new("i0")));
        let w = e.add_leaf(Op::Int(64));
        let start = e.add_op(Op::IMul, &[i, w]);
        let sl = e.add_op(Op::SliceAx { axis: 0, len: 64 }, &[start, x]);
        let eng = e.add_leaf(Op::ReluEngine { w: 64 });
        let inv = e.add_op(Op::InvokeRelu, &[eng, sl]);
        e.add_op(Op::SchedLoop { var: Symbol::new("i0"), axis: 0, extent: 2 }, &[inv]);
        let s = e.to_string();
        assert!(s.starts_with("(sched-loop i0 0 2 "), "{s}");
        assert!(s.contains("(slice 0 64 (imul (lvar i0) 64)"), "{s}");
    }
}
