//! The operator registry: one declarative [`OpSpec`] per [`OpKind`].
//!
//! This is the single place an operator is *described*: its s-expression
//! head, arity, attribute schema (how parameters print and parse), shape
//! rule, reference eval kernel, Relay→EngineIR lowering template, and cost
//! model (engine area/IO or host-fallback work). Every generic consumer —
//! the type checker ([`crate::ir::shape::infer_ref`]), the evaluator
//! ([`crate::tensor::eval`]), the printer/parser, the reification pass
//! ([`crate::lower`]), the analytic cost model and simulator — dispatches
//! through this table instead of matching on `Op` directly, so **adding an
//! operator means adding its `Op` variant and one entry here**; no other
//! match site in the crate grows an arm.
//!
//! Each entry also carries an `exemplar` s-expression with its expected
//! type: `tests/registry.rs` parses, prints, type-checks, evaluates, lowers
//! and costs every exemplar, so an op cannot land half-wired.

use super::op::{BufKind, ConstData, Op, OpKind};
use super::shape::{engine, in_dim, index, out_dim, shape_err, tensor, EngineSig};
use super::shape::{Shape, Ty, TypeError};
use super::symbol::Symbol;
use crate::egraph::Id;
use crate::error::Error;
use crate::lower::LowerCtx;
use crate::tensor::{EvalError, Tensor};
use std::collections::HashMap;
use std::sync::OnceLock;

/// Structural role of an op. Generic passes (eval, cost, sim, extraction)
/// switch on the *class*; per-op behavior within a class comes from the
/// spec's function fields. The `Index`, `Sched` and `Storage` classes are
/// closed structural features of the language; `Relay`, `Engine`, `Invoke`
/// and `Data` are open — new ops slot in without new match arms.
#[derive(Copy, Clone, PartialEq, Eq, Debug)]
pub enum OpClass {
    /// Integer index scalars (`Int`, `LVar`, `IMul`, `IAdd`).
    Index,
    /// Workload tensor leaves (`Input`, `Weight`).
    Leaf,
    /// Relay-level compute ops (unreified; host-fallback cost).
    Relay,
    /// Hardware engine declarations.
    Engine,
    /// Engine invocations (`[engine, tensor args...]`).
    Invoke,
    /// Software schedules (`sched-loop` / `sched-par` / `sched-reduce`).
    Sched,
    /// Data movement (slices, reshapes, broadcasts, layout transforms).
    Data,
    /// Storage materialization points (`buffer` / `dbl-buffer`).
    Storage,
}

/// Attribute slot kinds, schema-driving the parser.
#[derive(Copy, Clone, PartialEq, Eq, Debug)]
pub enum AttrKind {
    /// Unsigned size parameter.
    U,
    /// Signed integer literal.
    I,
    /// Interned symbol (names, loop variables).
    Sym,
    /// Static shape (`[a b c]`).
    Sh,
    /// Buffer kind (`sram` / `dram`).
    Buf,
    /// Inline f32 tensor data (`[1.5 -0.25 ...]`), printed with Rust's
    /// shortest-round-trip float formatting so parse ∘ print is bit-exact.
    F32s,
}

/// A concrete attribute value (printer output / parser input).
#[derive(Clone, Debug)]
pub enum AttrVal {
    U(usize),
    I(i64),
    Sym(Symbol),
    Sh(Shape),
    Buf(BufKind),
    F32s(Vec<f32>),
}

/// Join a shape's dims with `sep` (shared by the attr renderings).
fn dims(s: &Shape, sep: &str) -> String {
    let v: Vec<String> = s.0.iter().map(|d| d.to_string()).collect();
    v.join(sep)
}

impl AttrVal {
    pub fn u(&self) -> Option<usize> {
        match self {
            AttrVal::U(v) => Some(*v),
            _ => None,
        }
    }

    pub fn i(&self) -> Option<i64> {
        match self {
            AttrVal::I(v) => Some(*v),
            _ => None,
        }
    }

    pub fn sym(&self) -> Option<Symbol> {
        match self {
            AttrVal::Sym(s) => Some(*s),
            _ => None,
        }
    }

    pub fn sh(&self) -> Option<&Shape> {
        match self {
            AttrVal::Sh(s) => Some(s),
            _ => None,
        }
    }

    pub fn buf(&self) -> Option<BufKind> {
        match self {
            AttrVal::Buf(b) => Some(*b),
            _ => None,
        }
    }

    pub fn f32s(&self) -> Option<&[f32]> {
        match self {
            AttrVal::F32s(v) => Some(v),
            _ => None,
        }
    }

    /// Compact rendering for `Op`'s bracketed `Display` head form
    /// (`reshape[2,2]`): like [`Self::sexpr`] but shapes drop their own
    /// brackets, since the head form supplies the enclosing pair.
    pub fn compact(&self) -> String {
        match self {
            AttrVal::Sh(s) => dims(s, ","),
            other => other.sexpr(),
        }
    }

    /// The s-expression rendering of this attribute.
    pub fn sexpr(&self) -> String {
        match self {
            AttrVal::U(v) => v.to_string(),
            AttrVal::I(v) => v.to_string(),
            AttrVal::Sym(s) => s.to_string(),
            AttrVal::Sh(s) => format!("[{}]", dims(s, " ")),
            AttrVal::Buf(b) => b.as_str().to_string(),
            // `{:?}` is Rust's shortest round-trip float form, so
            // parse(print(x)) reproduces the exact bits.
            AttrVal::F32s(v) => {
                let parts: Vec<String> = v.iter().map(|x| format!("{x:?}")).collect();
                format!("[{}]", parts.join(" "))
            }
        }
    }
}

/// Area model class of an engine: MAC-array (matmul/conv) or lane-array
/// (elementwise/pool/normalization units).
#[derive(Copy, Clone, PartialEq, Eq, Debug)]
pub enum AreaClass {
    Mac,
    Lane,
}

/// Cost/identity description of a hardware engine declaration.
#[derive(Copy, Clone)]
pub struct EngineSpec {
    /// Multiply–accumulates of one invocation (area & energy basis).
    pub macs: fn(&Op) -> u64,
    /// MAC- or lane-class area pricing.
    pub area: AreaClass,
    /// I/O element count of one (maximal) invocation (streaming model).
    pub io: fn(&Op) -> f64,
    /// Elementwise-max parameter merge (baseline's "sized for the largest
    /// call"); both ops are guaranteed to be this spec's kind.
    pub merge_max: fn(&Op, &Op) -> Op,
    /// Output shape of one invocation.
    pub out_shape: fn(&Op) -> Shape,
}

/// Expected type of an exemplar term (golden for the registry tests).
#[derive(Copy, Clone, Debug)]
pub enum ExemplarTy {
    Index,
    Engine,
    Tensor(&'static [usize]),
}

/// Reference eval kernel: child/argument tensors in, output tensor out.
pub type EvalFn = fn(&Op, &[Tensor]) -> Result<Tensor, EvalError>;

/// One operator's complete description. See the module docs.
pub struct OpSpec {
    pub kind: OpKind,
    /// S-expression head symbol (`"conv2d"`, `"invoke-mm"`, …).
    pub name: &'static str,
    /// Fixed child count.
    pub arity: usize,
    pub class: OpClass,
    /// Attribute schema: `(display label, kind)` per slot, in print order.
    pub attrs: &'static [(&'static str, AttrKind)],
    /// Extract this op's attributes (printer side).
    pub attrs_of: fn(&Op) -> Vec<AttrVal>,
    /// Rebuild the op from parsed attributes (parser side).
    pub from_attrs: fn(&[AttrVal]) -> Option<Op>,
    /// Shape/type rule given child types.
    pub shape: fn(&Op, &[&Ty]) -> Result<Ty, TypeError>,
    /// Reference kernel for `Relay`/`Data` ops (`op` is the node's own op).
    pub eval: Option<EvalFn>,
    /// Oracle kernel for `Invoke` ops (`op` is the *engine* declaration).
    pub invoke_eval: Option<EvalFn>,
    /// Relay→EngineIR reification template (`Relay` ops and `Flatten`).
    pub lower: Option<fn(&mut LowerCtx) -> Result<Id, Error>>,
    /// Engine cost spec (`Engine` ops only).
    pub engine: Option<EngineSpec>,
    /// Host-fallback work model for unreified `Relay` ops:
    /// `(op, out shape, child shapes) -> ops`; default is `out.numel()`.
    pub host_work: Option<fn(&Op, &Shape, &[&Shape]) -> f64>,
    /// `Data` ops: true if the op materializes/moves elements (priced as
    /// SRAM traffic), false for free addressing/views.
    pub data_traffic: bool,
    /// `Engine` ops: the rule-name prefix of this engine's split-rewrite
    /// family (e.g. `"split-conv"` covers `split-conv-{oh,ow,k,c}-x2`).
    /// `None` on an engine is a **documented exemption** — the engine's
    /// computation is coupled across its whole width so no split exists
    /// (softmax/layernorm row engines). `tests/registry.rs` pins the
    /// exemption set and asserts every declared family has at least one
    /// registered rule, so a new engine can't ship split-less by accident.
    pub split_family: Option<&'static str>,
    /// A minimal closed term exercising this op (registry tests parse,
    /// print, type-check, evaluate, lower and cost it).
    pub exemplar: &'static str,
    pub exemplar_ty: ExemplarTy,
}

// ---------------------------------------------------------------------
// Shape rules (each mirrors one oracle kernel in `crate::tensor`)
// ---------------------------------------------------------------------

fn sh_index(_op: &Op, _tys: &[&Ty]) -> Result<Ty, TypeError> {
    Ok(Ty::Index)
}

fn sh_ibin(op: &Op, tys: &[&Ty]) -> Result<Ty, TypeError> {
    index(op, 0, tys)?;
    index(op, 1, tys)?;
    Ok(Ty::Index)
}

fn sh_leaf(op: &Op, _tys: &[&Ty]) -> Result<Ty, TypeError> {
    match op {
        Op::Input(_, sh) | Op::Weight(_, sh) => Ok(Ty::Tensor(sh.clone())),
        _ => unreachable!("sh_leaf on {op}"),
    }
}

fn sh_conv2d(op: &Op, tys: &[&Ty]) -> Result<Ty, TypeError> {
    let (stride, pad_h, pad_w) = match op {
        Op::Conv2d { stride, pad_h, pad_w } => (*stride, *pad_h, *pad_w),
        _ => unreachable!(),
    };
    let x = tensor(op, 0, tys)?;
    let w = tensor(op, 1, tys)?;
    if x.rank() != 3 || w.rank() != 4 {
        return Err(shape_err(op, format!("want x rank 3, w rank 4; got {x} {w}")));
    }
    let (c, h, wd) = (x.dim(0), x.dim(1), x.dim(2));
    let (kout, cin, kh, kw) = (w.dim(0), w.dim(1), w.dim(2), w.dim(3));
    if cin != c {
        return Err(shape_err(op, format!("channel mismatch: x{x} w{w}")));
    }
    let oh = out_dim(h + pad_h, kh, stride).ok_or_else(|| shape_err(op, "H does not tile"))?;
    let ow = out_dim(wd + pad_w, kw, stride).ok_or_else(|| shape_err(op, "W does not tile"))?;
    Ok(Ty::Tensor(Shape::new(&[kout, oh, ow])))
}

fn sh_const(op: &Op, _tys: &[&Ty]) -> Result<Ty, TypeError> {
    match op {
        Op::Constant(c) => Ok(Ty::Tensor(c.shape().clone())),
        _ => unreachable!("sh_const on {op}"),
    }
}

fn sh_dense(op: &Op, tys: &[&Ty]) -> Result<Ty, TypeError> {
    let x = tensor(op, 0, tys)?;
    let w = tensor(op, 1, tys)?;
    if x.rank() != 2 || w.rank() != 2 || x.dim(1) != w.dim(0) {
        return Err(shape_err(op, format!("matmul shapes x{x} w{w}")));
    }
    Ok(Ty::Tensor(Shape::new(&[x.dim(0), w.dim(1)])))
}

/// Output type = child-0 tensor type (elementwise ops, `sched-reduce`,
/// storage buffers).
fn sh_same(op: &Op, tys: &[&Ty]) -> Result<Ty, TypeError> {
    Ok(Ty::Tensor(tensor(op, 0, tys)?.clone()))
}

fn sh_bias_add(op: &Op, tys: &[&Ty]) -> Result<Ty, TypeError> {
    let x = tensor(op, 0, tys)?;
    let b = tensor(op, 1, tys)?;
    if b.rank() != 1 {
        return Err(shape_err(op, format!("bias must be rank 1, got {b}")));
    }
    let want = match x.rank() {
        3 => x.dim(0),
        2 => x.dim(1),
        _ => return Err(shape_err(op, format!("bias-add on rank {}", x.rank()))),
    };
    if b.dim(0) != want {
        return Err(shape_err(op, format!("bias {b} vs x {x}")));
    }
    Ok(Ty::Tensor(x.clone()))
}

fn sh_eadd(op: &Op, tys: &[&Ty]) -> Result<Ty, TypeError> {
    let x = tensor(op, 0, tys)?;
    let y = tensor(op, 1, tys)?;
    if x != y {
        return Err(shape_err(op, format!("eadd {x} vs {y}")));
    }
    Ok(Ty::Tensor(x.clone()))
}

fn sh_maxpool(op: &Op, tys: &[&Ty]) -> Result<Ty, TypeError> {
    let (kh, kw, stride) = match op {
        Op::MaxPool2d { kh, kw, stride } => (*kh, *kw, *stride),
        _ => unreachable!(),
    };
    let x = tensor(op, 0, tys)?;
    if x.rank() != 3 {
        return Err(shape_err(op, format!("maxpool on {x}")));
    }
    let oh = out_dim(x.dim(1), kh, stride).ok_or_else(|| shape_err(op, "H does not tile"))?;
    let ow = out_dim(x.dim(2), kw, stride).ok_or_else(|| shape_err(op, "W does not tile"))?;
    Ok(Ty::Tensor(Shape::new(&[x.dim(0), oh, ow])))
}

fn sh_flatten(op: &Op, tys: &[&Ty]) -> Result<Ty, TypeError> {
    let x = tensor(op, 0, tys)?;
    Ok(Ty::Tensor(Shape::new(&[1, x.numel()])))
}

fn sh_gap(op: &Op, tys: &[&Ty]) -> Result<Ty, TypeError> {
    let x = tensor(op, 0, tys)?;
    if x.rank() != 3 {
        return Err(shape_err(op, format!("gap on {x}")));
    }
    Ok(Ty::Tensor(Shape::new(&[x.dim(0)])))
}

fn sh_bmm(op: &Op, tys: &[&Ty]) -> Result<Ty, TypeError> {
    let a = tensor(op, 0, tys)?;
    let b = tensor(op, 1, tys)?;
    if a.rank() != 3 || b.rank() != 3 || a.dim(0) != b.dim(0) || a.dim(2) != b.dim(1) {
        return Err(shape_err(op, format!("batch-matmul shapes a{a} b{b}")));
    }
    Ok(Ty::Tensor(Shape::new(&[a.dim(0), a.dim(1), b.dim(2)])))
}

fn sh_transpose(op: &Op, tys: &[&Ty]) -> Result<Ty, TypeError> {
    let x = tensor(op, 0, tys)?;
    match x.rank() {
        2 => Ok(Ty::Tensor(Shape::new(&[x.dim(1), x.dim(0)]))),
        3 => Ok(Ty::Tensor(Shape::new(&[x.dim(0), x.dim(2), x.dim(1)]))),
        r => Err(shape_err(op, format!("transpose on rank {r}"))),
    }
}

/// Row-wise over the last axis; leading axes (up to rank 3, as in
/// multi-head attention's per-head score rows) are independent rows.
fn sh_rowwise(op: &Op, tys: &[&Ty]) -> Result<Ty, TypeError> {
    let x = tensor(op, 0, tys)?;
    if x.rank() < 1 || x.rank() > 3 {
        return Err(shape_err(op, format!("row-wise op on rank {}", x.rank())));
    }
    Ok(Ty::Tensor(x.clone()))
}

/// Affine layernorm: `x` rank 1 or 2, `gamma`/`beta` rank 1 of the
/// last-axis length.
fn sh_layernorm(op: &Op, tys: &[&Ty]) -> Result<Ty, TypeError> {
    let x = tensor(op, 0, tys)?;
    if x.rank() != 1 && x.rank() != 2 {
        return Err(shape_err(op, format!("layernorm on rank {}", x.rank())));
    }
    let n = x.dim(x.rank() - 1);
    let g = tensor(op, 1, tys)?;
    let b = tensor(op, 2, tys)?;
    if g != &Shape::new(&[n]) || b != &Shape::new(&[n]) {
        return Err(shape_err(op, format!("layernorm({n}) gamma{g} beta{b}")));
    }
    Ok(Ty::Tensor(x.clone()))
}

fn sh_dwconv2d(op: &Op, tys: &[&Ty]) -> Result<Ty, TypeError> {
    let (stride, pad_h, pad_w) = match op {
        Op::DepthwiseConv2d { stride, pad_h, pad_w } => (*stride, *pad_h, *pad_w),
        _ => unreachable!(),
    };
    let x = tensor(op, 0, tys)?;
    let w = tensor(op, 1, tys)?;
    if x.rank() != 3 || w.rank() != 3 {
        return Err(shape_err(op, format!("want x rank 3, w rank 3; got {x} {w}")));
    }
    if w.dim(0) != x.dim(0) {
        return Err(shape_err(op, format!("channel mismatch: x{x} w{w}")));
    }
    let oh = out_dim(x.dim(1) + pad_h, w.dim(1), stride)
        .ok_or_else(|| shape_err(op, "H does not tile"))?;
    let ow = out_dim(x.dim(2) + pad_w, w.dim(2), stride)
        .ok_or_else(|| shape_err(op, "W does not tile"))?;
    Ok(Ty::Tensor(Shape::new(&[x.dim(0), oh, ow])))
}

fn sh_engine(op: &Op, _tys: &[&Ty]) -> Result<Ty, TypeError> {
    Ok(Ty::Engine(EngineSig(op.clone())))
}

fn sh_invoke_mm(op: &Op, tys: &[&Ty]) -> Result<Ty, TypeError> {
    let e = engine(op, 0, tys)?;
    let (m, k, n) = match (op.kind(), e) {
        (OpKind::InvokeMm, Op::MmEngine { m, k, n }) => (*m, *k, *n),
        (OpKind::InvokeMmRelu, Op::MmReluEngine { m, k, n }) => (*m, *k, *n),
        _ => return Err(shape_err(op, format!("wrong engine {e}"))),
    };
    let a = tensor(op, 1, tys)?;
    let b = tensor(op, 2, tys)?;
    if a != &Shape::new(&[m, k]) || b != &Shape::new(&[k, n]) {
        return Err(shape_err(op, format!("mm({m},{k},{n}) got a{a} b{b}")));
    }
    Ok(Ty::Tensor(Shape::new(&[m, n])))
}

/// Shared shape rule for `w`-wide unary elementwise/row invocations
/// (relu, gelu, softmax, layernorm).
fn sh_invoke_elem(op: &Op, tys: &[&Ty]) -> Result<Ty, TypeError> {
    let e = engine(op, 0, tys)?;
    let w = match (op.kind(), e) {
        (OpKind::InvokeRelu, Op::ReluEngine { w })
        | (OpKind::InvokeGelu, Op::GeluEngine { w })
        | (OpKind::InvokeSoftmax, Op::SoftmaxEngine { w })
        | (OpKind::InvokeLayerNorm, Op::LayerNormEngine { w }) => *w,
        _ => return Err(shape_err(op, format!("wrong engine {e}"))),
    };
    let x = tensor(op, 1, tys)?;
    if x != &Shape::new(&[w]) {
        return Err(shape_err(op, format!("elem({w}) got {x}")));
    }
    Ok(Ty::Tensor(x.clone()))
}

/// Shared shape rule for `w`-wide binary elementwise invocations
/// (add, emul).
fn sh_invoke_add(op: &Op, tys: &[&Ty]) -> Result<Ty, TypeError> {
    let e = engine(op, 0, tys)?;
    let w = match (op.kind(), e) {
        (OpKind::InvokeAdd, Op::AddEngine { w })
        | (OpKind::InvokeEmul, Op::EmulEngine { w }) => *w,
        _ => return Err(shape_err(op, format!("wrong engine {e}"))),
    };
    let x = tensor(op, 1, tys)?;
    let y = tensor(op, 2, tys)?;
    if x != &Shape::new(&[w]) || y != &Shape::new(&[w]) {
        return Err(shape_err(op, format!("add({w}) got {x} {y}")));
    }
    Ok(Ty::Tensor(x.clone()))
}

fn sh_invoke_conv(op: &Op, tys: &[&Ty]) -> Result<Ty, TypeError> {
    let e = engine(op, 0, tys)?;
    let (oh, ow, c, k, kh, kw, stride) = match e {
        Op::ConvEngine { oh, ow, c, k, kh, kw, stride } => (*oh, *ow, *c, *k, *kh, *kw, *stride),
        _ => return Err(shape_err(op, format!("wrong engine {e}"))),
    };
    let x = tensor(op, 1, tys)?;
    let w = tensor(op, 2, tys)?;
    let want_x = Shape::new(&[c, in_dim(oh, kh, stride), in_dim(ow, kw, stride)]);
    let want_w = Shape::new(&[k, c, kh, kw]);
    if x != &want_x || w != &want_w {
        return Err(shape_err(
            op,
            format!("conv engine wants x{want_x} w{want_w}; got x{x} w{w}"),
        ));
    }
    Ok(Ty::Tensor(Shape::new(&[k, oh, ow])))
}

fn sh_invoke_pool(op: &Op, tys: &[&Ty]) -> Result<Ty, TypeError> {
    let e = engine(op, 0, tys)?;
    let (oh, ow, c, kh, kw, stride) = match e {
        Op::PoolEngine { oh, ow, c, kh, kw, stride } => (*oh, *ow, *c, *kh, *kw, *stride),
        _ => return Err(shape_err(op, format!("wrong engine {e}"))),
    };
    let x = tensor(op, 1, tys)?;
    let want = Shape::new(&[c, in_dim(oh, kh, stride), in_dim(ow, kw, stride)]);
    if x != &want {
        return Err(shape_err(op, format!("pool engine wants {want}; got {x}")));
    }
    Ok(Ty::Tensor(Shape::new(&[c, oh, ow])))
}

fn sh_invoke_dwconv(op: &Op, tys: &[&Ty]) -> Result<Ty, TypeError> {
    let e = engine(op, 0, tys)?;
    let (oh, ow, c, kh, kw, stride) = match e {
        Op::DwConvEngine { oh, ow, c, kh, kw, stride } => (*oh, *ow, *c, *kh, *kw, *stride),
        _ => return Err(shape_err(op, format!("wrong engine {e}"))),
    };
    let x = tensor(op, 1, tys)?;
    let w = tensor(op, 2, tys)?;
    let want_x = Shape::new(&[c, in_dim(oh, kh, stride), in_dim(ow, kw, stride)]);
    let want_w = Shape::new(&[c, kh, kw]);
    if x != &want_x || w != &want_w {
        return Err(shape_err(
            op,
            format!("dw-conv engine wants x{want_x} w{want_w}; got x{x} w{w}"),
        ));
    }
    Ok(Ty::Tensor(Shape::new(&[c, oh, ow])))
}

fn sh_sched_map(op: &Op, tys: &[&Ty]) -> Result<Ty, TypeError> {
    let (axis, extent) = match op {
        Op::SchedLoop { axis, extent, .. } | Op::SchedPar { axis, extent, .. } => {
            (*axis, *extent)
        }
        _ => unreachable!(),
    };
    let b = tensor(op, 0, tys)?;
    if axis >= b.rank() {
        return Err(shape_err(op, format!("axis {axis} out of range for {b}")));
    }
    Ok(Ty::Tensor(b.with_dim(axis, b.dim(axis) * extent)))
}

fn sh_slice(op: &Op, tys: &[&Ty]) -> Result<Ty, TypeError> {
    let (axis, len) = match op {
        Op::SliceAx { axis, len } => (*axis, *len),
        _ => unreachable!(),
    };
    index(op, 0, tys)?;
    let x = tensor(op, 1, tys)?;
    if axis >= x.rank() || len > x.dim(axis) {
        return Err(shape_err(op, format!("slice a{axis} l{len} of {x}")));
    }
    Ok(Ty::Tensor(x.with_dim(axis, len)))
}

fn sh_reshape(op: &Op, tys: &[&Ty]) -> Result<Ty, TypeError> {
    let sh = match op {
        Op::Reshape(sh) => sh,
        _ => unreachable!(),
    };
    let x = tensor(op, 0, tys)?;
    if x.numel() != sh.numel() {
        return Err(shape_err(op, format!("reshape {x} -> {sh}")));
    }
    Ok(Ty::Tensor(sh.clone()))
}

fn sh_bcast(op: &Op, tys: &[&Ty]) -> Result<Ty, TypeError> {
    let sh = match op {
        Op::Bcast(sh) => sh,
        _ => unreachable!(),
    };
    let b = tensor(op, 0, tys)?;
    if b.rank() != 1 {
        return Err(shape_err(op, format!("bcast of rank {}", b.rank())));
    }
    let ok = match sh.rank() {
        3 => sh.dim(0) == b.dim(0),
        2 => sh.dim(1) == b.dim(0),
        1 => sh.dim(0) == b.dim(0),
        _ => false,
    };
    if !ok {
        return Err(shape_err(op, format!("bcast {b} -> {sh}")));
    }
    Ok(Ty::Tensor(sh.clone()))
}

fn sh_pad2d(op: &Op, tys: &[&Ty]) -> Result<Ty, TypeError> {
    let (pad_h, pad_w) = match op {
        Op::Pad2d { pad_h, pad_w } => (*pad_h, *pad_w),
        _ => unreachable!(),
    };
    let x = tensor(op, 0, tys)?;
    if x.rank() != 3 {
        return Err(shape_err(op, format!("pad2d on {x}")));
    }
    Ok(Ty::Tensor(Shape::new(&[x.dim(0), x.dim(1) + pad_h, x.dim(2) + pad_w])))
}

fn sh_im2col(op: &Op, tys: &[&Ty]) -> Result<Ty, TypeError> {
    let (kh, kw, stride) = match op {
        Op::Im2Col { kh, kw, stride } => (*kh, *kw, *stride),
        _ => unreachable!(),
    };
    let x = tensor(op, 0, tys)?;
    if x.rank() != 3 {
        return Err(shape_err(op, format!("im2col on {x}")));
    }
    let oh = out_dim(x.dim(1), kh, stride).ok_or_else(|| shape_err(op, "H does not tile"))?;
    let ow = out_dim(x.dim(2), kw, stride).ok_or_else(|| shape_err(op, "W does not tile"))?;
    Ok(Ty::Tensor(Shape::new(&[x.dim(0) * kh * kw, oh * ow])))
}

// ---------------------------------------------------------------------
// Reference eval kernels (Relay/Data ops; args are the child tensors)
// ---------------------------------------------------------------------

fn ev_conv2d(op: &Op, args: &[Tensor]) -> Result<Tensor, EvalError> {
    let (stride, pad_h, pad_w) = match *op {
        Op::Conv2d { stride, pad_h, pad_w } => (stride, pad_h, pad_w),
        _ => unreachable!(),
    };
    let x = if pad_h > 0 || pad_w > 0 {
        args[0].pad2d(pad_h, pad_w)
    } else {
        args[0].clone()
    };
    Ok(x.conv2d(&args[1], stride))
}

fn ev_matmul(_op: &Op, args: &[Tensor]) -> Result<Tensor, EvalError> {
    Ok(args[0].matmul(&args[1]))
}

fn ev_relu(_op: &Op, args: &[Tensor]) -> Result<Tensor, EvalError> {
    Ok(args[0].relu())
}

fn ev_bias_add(_op: &Op, args: &[Tensor]) -> Result<Tensor, EvalError> {
    Ok(args[0].bias_add(&args[1]))
}

fn ev_eadd(_op: &Op, args: &[Tensor]) -> Result<Tensor, EvalError> {
    Ok(args[0].eadd(&args[1]))
}

fn ev_emul(_op: &Op, args: &[Tensor]) -> Result<Tensor, EvalError> {
    Ok(args[0].emul(&args[1]))
}

fn ev_maxpool(op: &Op, args: &[Tensor]) -> Result<Tensor, EvalError> {
    let (kh, kw, stride) = match *op {
        Op::MaxPool2d { kh, kw, stride } => (kh, kw, stride),
        _ => unreachable!(),
    };
    Ok(args[0].maxpool2d(kh, kw, stride))
}

fn ev_flatten(_op: &Op, args: &[Tensor]) -> Result<Tensor, EvalError> {
    let n = args[0].numel();
    Ok(args[0].reshape(Shape::new(&[1, n])))
}

fn ev_gap(_op: &Op, args: &[Tensor]) -> Result<Tensor, EvalError> {
    Ok(args[0].gap())
}

fn ev_bmm(_op: &Op, args: &[Tensor]) -> Result<Tensor, EvalError> {
    Ok(args[0].batch_matmul(&args[1]))
}

fn ev_transpose(_op: &Op, args: &[Tensor]) -> Result<Tensor, EvalError> {
    Ok(args[0].transpose_last())
}

fn ev_softmax(_op: &Op, args: &[Tensor]) -> Result<Tensor, EvalError> {
    Ok(args[0].softmax_last())
}

fn ev_layernorm(_op: &Op, args: &[Tensor]) -> Result<Tensor, EvalError> {
    Ok(args[0].layernorm_affine_last(&args[1], &args[2], 1e-5))
}

fn ev_gelu(_op: &Op, args: &[Tensor]) -> Result<Tensor, EvalError> {
    Ok(args[0].gelu())
}

fn ev_dwconv(op: &Op, args: &[Tensor]) -> Result<Tensor, EvalError> {
    let (stride, pad_h, pad_w) = match *op {
        Op::DepthwiseConv2d { stride, pad_h, pad_w } => (stride, pad_h, pad_w),
        _ => unreachable!(),
    };
    let x = if pad_h > 0 || pad_w > 0 {
        args[0].pad2d(pad_h, pad_w)
    } else {
        args[0].clone()
    };
    Ok(x.depthwise_conv2d(&args[1], stride))
}

fn ev_reshape(op: &Op, args: &[Tensor]) -> Result<Tensor, EvalError> {
    let sh = match op {
        Op::Reshape(sh) => sh.clone(),
        _ => unreachable!(),
    };
    Ok(args[0].reshape(sh))
}

fn ev_bcast(op: &Op, args: &[Tensor]) -> Result<Tensor, EvalError> {
    let sh = match op {
        Op::Bcast(sh) => sh.clone(),
        _ => unreachable!(),
    };
    Ok(args[0].bcast(sh))
}

fn ev_pad2d(op: &Op, args: &[Tensor]) -> Result<Tensor, EvalError> {
    let (pad_h, pad_w) = match *op {
        Op::Pad2d { pad_h, pad_w } => (pad_h, pad_w),
        _ => unreachable!(),
    };
    Ok(args[0].pad2d(pad_h, pad_w))
}

fn ev_im2col(op: &Op, args: &[Tensor]) -> Result<Tensor, EvalError> {
    let (kh, kw, stride) = match *op {
        Op::Im2Col { kh, kw, stride } => (kh, kw, stride),
        _ => unreachable!(),
    };
    Ok(args[0].im2col(kh, kw, stride))
}

// ---------------------------------------------------------------------
// Oracle invoke kernels (the op given is the *engine* declaration)
// ---------------------------------------------------------------------

fn iv_mm(_engine: &Op, args: &[Tensor]) -> Result<Tensor, EvalError> {
    Ok(args[0].matmul(&args[1]))
}

fn iv_mm_relu(_engine: &Op, args: &[Tensor]) -> Result<Tensor, EvalError> {
    Ok(args[0].matmul(&args[1]).relu())
}

fn iv_relu(_engine: &Op, args: &[Tensor]) -> Result<Tensor, EvalError> {
    Ok(args[0].relu())
}

fn iv_add(_engine: &Op, args: &[Tensor]) -> Result<Tensor, EvalError> {
    Ok(args[0].eadd(&args[1]))
}

fn iv_emul(_engine: &Op, args: &[Tensor]) -> Result<Tensor, EvalError> {
    Ok(args[0].emul(&args[1]))
}

fn iv_conv(engine: &Op, args: &[Tensor]) -> Result<Tensor, EvalError> {
    let stride = match engine {
        Op::ConvEngine { stride, .. } => *stride,
        _ => 1,
    };
    Ok(args[0].conv2d(&args[1], stride))
}

fn iv_pool(engine: &Op, args: &[Tensor]) -> Result<Tensor, EvalError> {
    let (kh, kw, stride) = match engine {
        Op::PoolEngine { kh, kw, stride, .. } => (*kh, *kw, *stride),
        _ => (1, 1, 1),
    };
    Ok(args[0].maxpool2d(kh, kw, stride))
}

fn iv_softmax(_engine: &Op, args: &[Tensor]) -> Result<Tensor, EvalError> {
    Ok(args[0].softmax_last())
}

fn iv_layernorm(_engine: &Op, args: &[Tensor]) -> Result<Tensor, EvalError> {
    Ok(args[0].layernorm_last(1e-5))
}

fn iv_gelu(_engine: &Op, args: &[Tensor]) -> Result<Tensor, EvalError> {
    Ok(args[0].gelu())
}

fn iv_dwconv(engine: &Op, args: &[Tensor]) -> Result<Tensor, EvalError> {
    let stride = match engine {
        Op::DwConvEngine { stride, .. } => *stride,
        _ => 1,
    };
    Ok(args[0].depthwise_conv2d(&args[1], stride))
}

// ---------------------------------------------------------------------
// Lowering templates (paper Fig. 1 reification, one per Relay op)
// ---------------------------------------------------------------------

/// `dense`/`matmul` → `buffer (invoke-mm (mm-engine m k n) a b)`.
fn lo_mm(cx: &mut LowerCtx) -> Result<Id, Error> {
    let x = cx.child_shape(0)?;
    let w = cx.child_shape(1)?;
    let (m, k, n) = (x.dim(0), x.dim(1), w.dim(1));
    let a = cx.kid(0);
    let b = cx.kid(1);
    let e = cx.add_leaf(Op::MmEngine { m, k, n });
    let inv = cx.add(Op::InvokeMm, &[e, a, b]);
    Ok(cx.buffered(inv))
}

/// Shared template for whole-tensor elementwise units (relu, gelu):
/// flatten → invoke on a numel-wide engine → reshape back.
fn lo_elementwise(cx: &mut LowerCtx, mk_engine: fn(usize) -> Op, invoke: Op) -> Result<Id, Error> {
    let s = cx.out_shape()?;
    let xs = cx.child_shape(0)?;
    let x0 = cx.kid(0);
    let e = cx.add_leaf(mk_engine(s.numel()));
    let xin = cx.flat(x0, &xs);
    let inv = cx.add(invoke, &[e, xin]);
    let backed = cx.unflat(inv, &s);
    Ok(cx.buffered(backed))
}

fn lo_relu(cx: &mut LowerCtx) -> Result<Id, Error> {
    lo_elementwise(cx, |w| Op::ReluEngine { w }, Op::InvokeRelu)
}

fn lo_gelu(cx: &mut LowerCtx) -> Result<Id, Error> {
    lo_elementwise(cx, |w| Op::GeluEngine { w }, Op::InvokeGelu)
}

/// Shared template for whole-tensor binary elementwise units (eadd, emul):
/// flatten both operands → invoke on a numel-wide engine → reshape back.
fn lo_ebin(cx: &mut LowerCtx, mk_engine: fn(usize) -> Op, invoke: Op) -> Result<Id, Error> {
    let s = cx.out_shape()?;
    let s0 = cx.child_shape(0)?;
    let s1 = cx.child_shape(1)?;
    let a0 = cx.kid(0);
    let b0 = cx.kid(1);
    let e = cx.add_leaf(mk_engine(s.numel()));
    let a = cx.flat(a0, &s0);
    let b = cx.flat(b0, &s1);
    let inv = cx.add(invoke, &[e, a, b]);
    let backed = cx.unflat(inv, &s);
    Ok(cx.buffered(backed))
}

fn lo_eadd(cx: &mut LowerCtx) -> Result<Id, Error> {
    lo_ebin(cx, |w| Op::AddEngine { w }, Op::InvokeAdd)
}

fn lo_emul(cx: &mut LowerCtx) -> Result<Id, Error> {
    lo_ebin(cx, |w| Op::EmulEngine { w }, Op::InvokeEmul)
}

fn lo_bias_add(cx: &mut LowerCtx) -> Result<Id, Error> {
    let s = cx.out_shape()?;
    let s0 = cx.child_shape(0)?;
    let a0 = cx.kid(0);
    let b0 = cx.kid(1);
    let e = cx.add_leaf(Op::AddEngine { w: s.numel() });
    let a = cx.flat(a0, &s0);
    let bb = cx.add(Op::Bcast(s.clone()), &[b0]);
    let b = cx.flat(bb, &s);
    let inv = cx.add(Op::InvokeAdd, &[e, a, b]);
    let backed = cx.unflat(inv, &s);
    Ok(cx.buffered(backed))
}

fn lo_conv2d(cx: &mut LowerCtx) -> Result<Id, Error> {
    let (stride, pad_h, pad_w) = match *cx.op() {
        Op::Conv2d { stride, pad_h, pad_w } => (stride, pad_h, pad_w),
        _ => unreachable!(),
    };
    let x = cx.child_shape(0)?;
    let w = cx.child_shape(1)?;
    let o = cx.out_shape()?;
    let (c, k, kh, kw) = (x.dim(0), w.dim(0), w.dim(2), w.dim(3));
    let (oh, ow) = (o.dim(1), o.dim(2));
    debug_assert_eq!(in_dim(oh, kh, stride), x.dim(1) + pad_h);
    debug_assert_eq!(in_dim(ow, kw, stride), x.dim(2) + pad_w);
    let x0 = cx.kid(0);
    let w0 = cx.kid(1);
    let e = cx.add_leaf(Op::ConvEngine { oh, ow, c, k, kh, kw, stride });
    let xin = if pad_h > 0 || pad_w > 0 {
        cx.add(Op::Pad2d { pad_h, pad_w }, &[x0])
    } else {
        x0
    };
    let inv = cx.add(Op::InvokeConv, &[e, xin, w0]);
    Ok(cx.buffered(inv))
}

fn lo_maxpool(cx: &mut LowerCtx) -> Result<Id, Error> {
    let (kh, kw, stride) = match *cx.op() {
        Op::MaxPool2d { kh, kw, stride } => (kh, kw, stride),
        _ => unreachable!(),
    };
    let x = cx.child_shape(0)?;
    let o = cx.out_shape()?;
    let x0 = cx.kid(0);
    let e = cx.add_leaf(Op::PoolEngine {
        oh: o.dim(1),
        ow: o.dim(2),
        c: x.dim(0),
        kh,
        kw,
        stride,
    });
    let inv = cx.add(Op::InvokePool, &[e, x0]);
    Ok(cx.buffered(inv))
}

fn lo_flatten(cx: &mut LowerCtx) -> Result<Id, Error> {
    let s = cx.out_shape()?;
    let x0 = cx.kid(0);
    Ok(cx.add(Op::Reshape(s), &[x0]))
}

/// Rank-recursive reification core for row-coupled units (softmax,
/// layernorm's normalization half): rank-1 tensors invoke directly;
/// rank-2 tensors become a `sched-loop` over per-row invocations; rank-3
/// tensors (per-head attention scores) add an outer `sched-loop` over the
/// leading axis — every initial design point exposes schedules the
/// `parallelize` rewrite can act on. Returns the *unbuffered* result.
fn rowwise_core(
    cx: &mut LowerCtx,
    mk_engine: fn(usize) -> Op,
    invoke: &Op,
    x: Id,
    s: &Shape,
) -> Result<Id, Error> {
    match s.rank() {
        1 => {
            let e = cx.add_leaf(mk_engine(s.dim(0)));
            Ok(cx.add(invoke.clone(), &[e, x]))
        }
        2 => {
            let (m, n) = (s.dim(0), s.dim(1));
            let var = Symbol::fresh("rw");
            let sl = cx.loop_slice(var, 0, 1, 1, x);
            let row = cx.add(Op::Reshape(Shape::new(&[n])), &[sl]);
            let e = cx.add_leaf(mk_engine(n));
            let inv = cx.add(invoke.clone(), &[e, row]);
            let back = cx.add(Op::Reshape(Shape::new(&[1, n])), &[inv]);
            Ok(cx.add(Op::SchedLoop { var, axis: 0, extent: m }, &[back]))
        }
        3 => {
            let (b, m, n) = (s.dim(0), s.dim(1), s.dim(2));
            let var = Symbol::fresh("rb");
            let sl = cx.loop_slice(var, 0, 1, 1, x);
            let mat = cx.add(Op::Reshape(Shape::new(&[m, n])), &[sl]);
            let inner = rowwise_core(cx, mk_engine, invoke, mat, &Shape::new(&[m, n]))?;
            let back = cx.add(Op::Reshape(Shape::new(&[1, m, n])), &[inner]);
            Ok(cx.add(Op::SchedLoop { var, axis: 0, extent: b }, &[back]))
        }
        r => Err(cx.lower_err(format!("row-wise op on rank {r}"))),
    }
}

fn lo_softmax(cx: &mut LowerCtx) -> Result<Id, Error> {
    let s = cx.out_shape()?;
    let x0 = cx.kid(0);
    let core = rowwise_core(cx, |w| Op::SoftmaxEngine { w }, &Op::InvokeSoftmax, x0, &s)?;
    Ok(cx.buffered(core))
}

/// Affine layernorm: the row-coupled normalization half runs on the
/// `layernorm-engine` (per-row schedule, exactly as before), then the
/// affine tail — `gamma ⊙ · + beta` — runs on numel-wide `emul-engine` /
/// `add-engine` invocations over broadcast gamma/beta.
fn lo_layernorm(cx: &mut LowerCtx) -> Result<Id, Error> {
    let s = cx.out_shape()?;
    let x0 = cx.kid(0);
    let g0 = cx.kid(1);
    let b0 = cx.kid(2);
    let norm = rowwise_core(cx, |w| Op::LayerNormEngine { w }, &Op::InvokeLayerNorm, x0, &s)?;
    let gb = cx.add(Op::Bcast(s.clone()), &[g0]);
    let bb = cx.add(Op::Bcast(s.clone()), &[b0]);
    let fx = cx.flat(norm, &s);
    let fg = cx.flat(gb, &s);
    let fb = cx.flat(bb, &s);
    let em = cx.add_leaf(Op::EmulEngine { w: s.numel() });
    let scaled = cx.add(Op::InvokeEmul, &[em, fx, fg]);
    let ae = cx.add_leaf(Op::AddEngine { w: s.numel() });
    let shifted = cx.add(Op::InvokeAdd, &[ae, scaled, fb]);
    let backed = cx.unflat(shifted, &s);
    Ok(cx.buffered(backed))
}

/// `batch-matmul` → `sched-loop` over the batch with per-slice `invoke-mm`
/// (the mm engine is shared across iterations by hashconsing; mm split
/// rewrites then apply inside the loop).
fn lo_bmm(cx: &mut LowerCtx) -> Result<Id, Error> {
    let a = cx.child_shape(0)?;
    let b = cx.child_shape(1)?;
    let (bt, m, k, n) = (a.dim(0), a.dim(1), a.dim(2), b.dim(2));
    let var = Symbol::fresh("b");
    let a0 = cx.kid(0);
    let b0 = cx.kid(1);
    let sa = cx.loop_slice(var, 0, 1, 1, a0);
    let sb = cx.loop_slice(var, 0, 1, 1, b0);
    let ra = cx.add(Op::Reshape(Shape::new(&[m, k])), &[sa]);
    let rb = cx.add(Op::Reshape(Shape::new(&[k, n])), &[sb]);
    let e = cx.add_leaf(Op::MmEngine { m, k, n });
    let inv = cx.add(Op::InvokeMm, &[e, ra, rb]);
    let back = cx.add(Op::Reshape(Shape::new(&[1, m, n])), &[inv]);
    let lp = cx.add(Op::SchedLoop { var, axis: 0, extent: bt }, &[back]);
    Ok(cx.buffered(lp))
}

fn lo_dwconv(cx: &mut LowerCtx) -> Result<Id, Error> {
    let (stride, pad_h, pad_w) = match *cx.op() {
        Op::DepthwiseConv2d { stride, pad_h, pad_w } => (stride, pad_h, pad_w),
        _ => unreachable!(),
    };
    let x = cx.child_shape(0)?;
    let w = cx.child_shape(1)?;
    let o = cx.out_shape()?;
    let x0 = cx.kid(0);
    let w0 = cx.kid(1);
    let e = cx.add_leaf(Op::DwConvEngine {
        oh: o.dim(1),
        ow: o.dim(2),
        c: x.dim(0),
        kh: w.dim(1),
        kw: w.dim(2),
        stride,
    });
    let xin = if pad_h > 0 || pad_w > 0 {
        cx.add(Op::Pad2d { pad_h, pad_w }, &[x0])
    } else {
        x0
    };
    let inv = cx.add(Op::InvokeDwConv, &[e, xin, w0]);
    Ok(cx.buffered(inv))
}

// ---------------------------------------------------------------------
// Host-fallback work models (unreified Relay ops; default out.numel())
// ---------------------------------------------------------------------

fn hw_mm(_op: &Op, out: &Shape, ch: &[&Shape]) -> f64 {
    out.numel() as f64 * ch[0].dim(1) as f64
}

fn hw_bmm(_op: &Op, out: &Shape, ch: &[&Shape]) -> f64 {
    out.numel() as f64 * ch[0].dim(2) as f64
}

fn hw_conv(_op: &Op, out: &Shape, ch: &[&Shape]) -> f64 {
    out.numel() as f64 * (ch[1].dim(1) * ch[1].dim(2) * ch[1].dim(3)) as f64
}

fn hw_dwconv(_op: &Op, out: &Shape, ch: &[&Shape]) -> f64 {
    out.numel() as f64 * (ch[1].dim(1) * ch[1].dim(2)) as f64
}

fn hw_rowwise(_op: &Op, out: &Shape, _ch: &[&Shape]) -> f64 {
    // Multi-pass row reductions (max/exp/sum or mean/var/normalize).
    4.0 * out.numel() as f64
}

// ---------------------------------------------------------------------
// Engine cost specs
// ---------------------------------------------------------------------

fn mm_params(op: &Op) -> (usize, usize, usize) {
    match *op {
        Op::MmEngine { m, k, n } | Op::MmReluEngine { m, k, n } => (m, k, n),
        _ => unreachable!("mm_params on {op}"),
    }
}

fn mm_macs(op: &Op) -> u64 {
    let (m, k, n) = mm_params(op);
    (m * k * n) as u64
}

fn mm_io(op: &Op) -> f64 {
    let (m, k, n) = mm_params(op);
    (m * k + k * n + m * n) as f64
}

fn mm_merge(a: &Op, b: &Op) -> Op {
    let (m, k, n) = mm_params(a);
    let (m2, k2, n2) = mm_params(b);
    let (m, k, n) = (m.max(m2), k.max(k2), n.max(n2));
    match a {
        Op::MmEngine { .. } => Op::MmEngine { m, k, n },
        _ => Op::MmReluEngine { m, k, n },
    }
}

fn mm_out(op: &Op) -> Shape {
    let (m, _, n) = mm_params(op);
    Shape::new(&[m, n])
}

/// Width of a `w`-parameterized vector/row engine.
fn w_param(op: &Op) -> usize {
    match *op {
        Op::ReluEngine { w }
        | Op::AddEngine { w }
        | Op::EmulEngine { w }
        | Op::GeluEngine { w }
        | Op::SoftmaxEngine { w }
        | Op::LayerNormEngine { w } => w,
        _ => unreachable!("w_param on {op}"),
    }
}

fn w_macs(op: &Op) -> u64 {
    w_param(op) as u64
}

/// Softmax/layernorm do several passes over the row (max/exp/sum or
/// mean/var/normalize): charge 4 lanes-worth per element.
fn w_macs_x4(op: &Op) -> u64 {
    4 * w_param(op) as u64
}

fn w_io2(op: &Op) -> f64 {
    2.0 * w_param(op) as f64
}

fn w_io3(op: &Op) -> f64 {
    3.0 * w_param(op) as f64
}

fn w_merge(a: &Op, b: &Op) -> Op {
    let w = w_param(a).max(w_param(b));
    match a {
        Op::ReluEngine { .. } => Op::ReluEngine { w },
        Op::AddEngine { .. } => Op::AddEngine { w },
        Op::EmulEngine { .. } => Op::EmulEngine { w },
        Op::GeluEngine { .. } => Op::GeluEngine { w },
        Op::SoftmaxEngine { .. } => Op::SoftmaxEngine { w },
        Op::LayerNormEngine { .. } => Op::LayerNormEngine { w },
        _ => unreachable!(),
    }
}

fn w_out(op: &Op) -> Shape {
    Shape::new(&[w_param(op)])
}

fn conv_macs(op: &Op) -> u64 {
    match *op {
        Op::ConvEngine { oh, ow, c, k, kh, kw, .. } => (oh * ow * c * k * kh * kw) as u64,
        _ => unreachable!(),
    }
}

fn conv_io(op: &Op) -> f64 {
    match *op {
        Op::ConvEngine { oh, ow, c, k, kh, kw, stride } => {
            let ih = in_dim(oh, kh, stride);
            let iw = in_dim(ow, kw, stride);
            (c * ih * iw + k * c * kh * kw + k * oh * ow) as f64
        }
        _ => unreachable!(),
    }
}

fn conv_merge(a: &Op, b: &Op) -> Op {
    match (a, b) {
        (
            Op::ConvEngine { oh, ow, c, k, kh, kw, stride },
            Op::ConvEngine { oh: a1, ow: a2, c: a3, k: a4, kh: a5, kw: a6, stride: _ },
        ) => Op::ConvEngine {
            oh: (*oh).max(*a1),
            ow: (*ow).max(*a2),
            c: (*c).max(*a3),
            k: (*k).max(*a4),
            kh: (*kh).max(*a5),
            kw: (*kw).max(*a6),
            stride: *stride,
        },
        _ => unreachable!(),
    }
}

fn conv_out(op: &Op) -> Shape {
    match *op {
        Op::ConvEngine { oh, ow, k, .. } => Shape::new(&[k, oh, ow]),
        _ => unreachable!(),
    }
}

fn pool_macs(op: &Op) -> u64 {
    match *op {
        Op::PoolEngine { oh, ow, c, kh, kw, .. } => (oh * ow * c * kh * kw) as u64,
        _ => unreachable!(),
    }
}

fn pool_io(op: &Op) -> f64 {
    match *op {
        Op::PoolEngine { oh, ow, c, kh, kw, stride } => {
            let ih = in_dim(oh, kh, stride);
            let iw = in_dim(ow, kw, stride);
            (c * ih * iw + c * oh * ow) as f64
        }
        _ => unreachable!(),
    }
}

fn pool_merge(a: &Op, b: &Op) -> Op {
    match (a, b) {
        (
            Op::PoolEngine { oh, ow, c, kh, kw, stride },
            Op::PoolEngine { oh: b1, ow: b2, c: b3, kh: b4, kw: b5, stride: _ },
        ) => Op::PoolEngine {
            oh: (*oh).max(*b1),
            ow: (*ow).max(*b2),
            c: (*c).max(*b3),
            kh: (*kh).max(*b4),
            kw: (*kw).max(*b5),
            stride: *stride,
        },
        _ => unreachable!(),
    }
}

fn pool_out(op: &Op) -> Shape {
    match *op {
        Op::PoolEngine { oh, ow, c, .. } => Shape::new(&[c, oh, ow]),
        _ => unreachable!(),
    }
}

fn dwconv_macs(op: &Op) -> u64 {
    match *op {
        Op::DwConvEngine { oh, ow, c, kh, kw, .. } => (oh * ow * c * kh * kw) as u64,
        _ => unreachable!(),
    }
}

fn dwconv_io(op: &Op) -> f64 {
    match *op {
        Op::DwConvEngine { oh, ow, c, kh, kw, stride } => {
            let ih = in_dim(oh, kh, stride);
            let iw = in_dim(ow, kw, stride);
            (c * ih * iw + c * kh * kw + c * oh * ow) as f64
        }
        _ => unreachable!(),
    }
}

fn dwconv_merge(a: &Op, b: &Op) -> Op {
    match (a, b) {
        (
            Op::DwConvEngine { oh, ow, c, kh, kw, stride },
            Op::DwConvEngine { oh: b1, ow: b2, c: b3, kh: b4, kw: b5, stride: _ },
        ) => Op::DwConvEngine {
            oh: (*oh).max(*b1),
            ow: (*ow).max(*b2),
            c: (*c).max(*b3),
            kh: (*kh).max(*b4),
            kw: (*kw).max(*b5),
            stride: *stride,
        },
        _ => unreachable!(),
    }
}

fn dwconv_out(op: &Op) -> Shape {
    match *op {
        Op::DwConvEngine { oh, ow, c, .. } => Shape::new(&[c, oh, ow]),
        _ => unreachable!(),
    }
}

// ---------------------------------------------------------------------
// The registry
// ---------------------------------------------------------------------

use self::AttrKind as A;
use self::ExemplarTy as X;
use self::OpClass as C;

/// Baseline entry: unit op of the given class. Entries override fields via
/// struct-update syntax.
fn base(
    kind: OpKind,
    name: &'static str,
    arity: usize,
    class: OpClass,
    shape: fn(&Op, &[&Ty]) -> Result<Ty, TypeError>,
) -> OpSpec {
    OpSpec {
        kind,
        name,
        arity,
        class,
        attrs: &[],
        attrs_of: |_| Vec::new(),
        from_attrs: |_| None,
        shape,
        eval: None,
        invoke_eval: None,
        lower: None,
        engine: None,
        host_work: None,
        data_traffic: false,
        split_family: None,
        exemplar: "",
        exemplar_ty: X::Index,
    }
}

const MM_COST: EngineSpec = EngineSpec {
    macs: mm_macs,
    area: AreaClass::Mac,
    io: mm_io,
    merge_max: mm_merge,
    out_shape: mm_out,
};

const CONV_COST: EngineSpec = EngineSpec {
    macs: conv_macs,
    area: AreaClass::Mac,
    io: conv_io,
    merge_max: conv_merge,
    out_shape: conv_out,
};

const POOL_COST: EngineSpec = EngineSpec {
    macs: pool_macs,
    area: AreaClass::Lane,
    io: pool_io,
    merge_max: pool_merge,
    out_shape: pool_out,
};

const DWCONV_COST: EngineSpec = EngineSpec {
    macs: dwconv_macs,
    area: AreaClass::Mac,
    io: dwconv_io,
    merge_max: dwconv_merge,
    out_shape: dwconv_out,
};

/// Lane-class `w`-wide engine cost spec (relu/add/gelu: `macs` = `w`).
const LANE_COST: EngineSpec = EngineSpec {
    macs: w_macs,
    area: AreaClass::Lane,
    io: w_io2,
    merge_max: w_merge,
    out_shape: w_out,
};

/// Row-reduction engines (softmax/layernorm): multi-pass, 4 lanes/element.
const ROW_COST: EngineSpec = EngineSpec {
    macs: w_macs_x4,
    area: AreaClass::Lane,
    io: w_io2,
    merge_max: w_merge,
    out_shape: w_out,
};

fn build_specs() -> Vec<OpSpec> {
    vec![
        // ---- index scalars ------------------------------------------------
        OpSpec {
            attrs: &[("", A::I)],
            attrs_of: |op| match op {
                Op::Int(v) => vec![AttrVal::I(*v)],
                _ => unreachable!(),
            },
            from_attrs: |a| Some(Op::Int(a[0].i()?)),
            exemplar: "7",
            ..base(OpKind::Int, "int", 0, C::Index, sh_index)
        },
        OpSpec {
            attrs: &[("", A::Sym)],
            attrs_of: |op| match op {
                Op::LVar(s) => vec![AttrVal::Sym(*s)],
                _ => unreachable!(),
            },
            from_attrs: |a| Some(Op::LVar(a[0].sym()?)),
            exemplar: "(lvar i)",
            ..base(OpKind::LVar, "lvar", 0, C::Index, sh_index)
        },
        OpSpec {
            from_attrs: |_| Some(Op::IMul),
            exemplar: "(imul 2 3)",
            ..base(OpKind::IMul, "imul", 2, C::Index, sh_ibin)
        },
        OpSpec {
            from_attrs: |_| Some(Op::IAdd),
            exemplar: "(iadd 2 3)",
            ..base(OpKind::IAdd, "iadd", 2, C::Index, sh_ibin)
        },
        // ---- workload tensor leaves --------------------------------------
        OpSpec {
            attrs: &[("", A::Sym), ("", A::Sh)],
            attrs_of: |op| match op {
                Op::Input(s, sh) => vec![AttrVal::Sym(*s), AttrVal::Sh(sh.clone())],
                _ => unreachable!(),
            },
            from_attrs: |a| Some(Op::Input(a[0].sym()?, a[1].sh()?.clone())),
            exemplar: "(input x [4 4])",
            exemplar_ty: X::Tensor(&[4, 4]),
            ..base(OpKind::Input, "input", 0, C::Leaf, sh_leaf)
        },
        OpSpec {
            attrs: &[("", A::Sym), ("", A::Sh)],
            attrs_of: |op| match op {
                Op::Weight(s, sh) => vec![AttrVal::Sym(*s), AttrVal::Sh(sh.clone())],
                _ => unreachable!(),
            },
            from_attrs: |a| Some(Op::Weight(a[0].sym()?, a[1].sh()?.clone())),
            exemplar: "(weight w [8])",
            exemplar_ty: X::Tensor(&[8]),
            ..base(OpKind::Weight, "weight", 0, C::Leaf, sh_leaf)
        },
        // ---- Relay-level compute -----------------------------------------
        OpSpec {
            attrs: &[("s", A::U), ("ph", A::U), ("pw", A::U)],
            attrs_of: |op| match op {
                Op::Conv2d { stride, pad_h, pad_w } => {
                    vec![AttrVal::U(*stride), AttrVal::U(*pad_h), AttrVal::U(*pad_w)]
                }
                _ => unreachable!(),
            },
            from_attrs: |a| {
                Some(Op::Conv2d { stride: a[0].u()?, pad_h: a[1].u()?, pad_w: a[2].u()? })
            },
            eval: Some(ev_conv2d),
            lower: Some(lo_conv2d),
            host_work: Some(hw_conv),
            exemplar: "(conv2d 1 0 0 (input x [3 8 8]) (weight w [4 3 3 3]))",
            exemplar_ty: X::Tensor(&[4, 6, 6]),
            ..base(OpKind::Conv2d, "conv2d", 2, C::Relay, sh_conv2d)
        },
        OpSpec {
            from_attrs: |_| Some(Op::Dense),
            eval: Some(ev_matmul),
            lower: Some(lo_mm),
            host_work: Some(hw_mm),
            exemplar: "(dense (input x [2 8]) (weight w [8 4]))",
            exemplar_ty: X::Tensor(&[2, 4]),
            ..base(OpKind::Dense, "dense", 2, C::Relay, sh_dense)
        },
        OpSpec {
            from_attrs: |_| Some(Op::Relu),
            eval: Some(ev_relu),
            lower: Some(lo_relu),
            exemplar: "(relu (input x [8]))",
            exemplar_ty: X::Tensor(&[8]),
            ..base(OpKind::Relu, "relu", 1, C::Relay, sh_same)
        },
        OpSpec {
            from_attrs: |_| Some(Op::BiasAdd),
            eval: Some(ev_bias_add),
            lower: Some(lo_bias_add),
            exemplar: "(bias-add (input x [2 4]) (weight b [4]))",
            exemplar_ty: X::Tensor(&[2, 4]),
            ..base(OpKind::BiasAdd, "bias-add", 2, C::Relay, sh_bias_add)
        },
        OpSpec {
            from_attrs: |_| Some(Op::EAdd),
            eval: Some(ev_eadd),
            lower: Some(lo_eadd),
            exemplar: "(eadd (input x [4]) (input y [4]))",
            exemplar_ty: X::Tensor(&[4]),
            ..base(OpKind::EAdd, "eadd", 2, C::Relay, sh_eadd)
        },
        OpSpec {
            attrs: &[("kh", A::U), ("kw", A::U), ("s", A::U)],
            attrs_of: |op| match op {
                Op::MaxPool2d { kh, kw, stride } => {
                    vec![AttrVal::U(*kh), AttrVal::U(*kw), AttrVal::U(*stride)]
                }
                _ => unreachable!(),
            },
            from_attrs: |a| {
                Some(Op::MaxPool2d { kh: a[0].u()?, kw: a[1].u()?, stride: a[2].u()? })
            },
            eval: Some(ev_maxpool),
            lower: Some(lo_maxpool),
            // Deliberately non-square: pins the rectangular window through
            // the whole parse/print/shape/eval/lower/cost harness.
            exemplar: "(maxpool2d 2 4 2 (input x [3 8 8]))",
            exemplar_ty: X::Tensor(&[3, 4, 3]),
            ..base(OpKind::MaxPool2d, "maxpool2d", 1, C::Relay, sh_maxpool)
        },
        OpSpec {
            from_attrs: |_| Some(Op::Flatten),
            eval: Some(ev_flatten),
            lower: Some(lo_flatten),
            exemplar: "(flatten (input x [2 3]))",
            exemplar_ty: X::Tensor(&[1, 6]),
            ..base(OpKind::Flatten, "flatten", 1, C::Relay, sh_flatten)
        },
        OpSpec {
            from_attrs: |_| Some(Op::GlobalAvgPool),
            eval: Some(ev_gap),
            lower: None, // no engine form yet: gap stays host-side
            exemplar: "(gap (input x [3 4 4]))",
            exemplar_ty: X::Tensor(&[3]),
            ..base(OpKind::GlobalAvgPool, "gap", 1, C::Relay, sh_gap)
        },
        // ---- engines ------------------------------------------------------
        OpSpec {
            attrs: &[("", A::U), ("", A::U), ("", A::U)],
            attrs_of: |op| match op {
                Op::MmEngine { m, k, n } => {
                    vec![AttrVal::U(*m), AttrVal::U(*k), AttrVal::U(*n)]
                }
                _ => unreachable!(),
            },
            from_attrs: |a| Some(Op::MmEngine { m: a[0].u()?, k: a[1].u()?, n: a[2].u()? }),
            engine: Some(MM_COST),
            split_family: Some("split-mm"),
            exemplar: "(mm-engine 4 4 4)",
            exemplar_ty: X::Engine,
            ..base(OpKind::MmEngine, "mm-engine", 0, C::Engine, sh_engine)
        },
        OpSpec {
            attrs: &[("", A::U), ("", A::U), ("", A::U)],
            attrs_of: |op| match op {
                Op::MmReluEngine { m, k, n } => {
                    vec![AttrVal::U(*m), AttrVal::U(*k), AttrVal::U(*n)]
                }
                _ => unreachable!(),
            },
            from_attrs: |a| {
                Some(Op::MmReluEngine { m: a[0].u()?, k: a[1].u()?, n: a[2].u()? })
            },
            engine: Some(MM_COST),
            split_family: Some("split-mmrelu"),
            exemplar: "(mm-relu-engine 4 4 4)",
            exemplar_ty: X::Engine,
            ..base(OpKind::MmReluEngine, "mm-relu-engine", 0, C::Engine, sh_engine)
        },
        OpSpec {
            attrs: &[("", A::U)],
            attrs_of: |op| match op {
                Op::ReluEngine { w } => vec![AttrVal::U(*w)],
                _ => unreachable!(),
            },
            from_attrs: |a| Some(Op::ReluEngine { w: a[0].u()? }),
            engine: Some(LANE_COST),
            split_family: Some("split-relu"),
            exemplar: "(relu-engine 8)",
            exemplar_ty: X::Engine,
            ..base(OpKind::ReluEngine, "relu-engine", 0, C::Engine, sh_engine)
        },
        OpSpec {
            attrs: &[("", A::U)],
            attrs_of: |op| match op {
                Op::AddEngine { w } => vec![AttrVal::U(*w)],
                _ => unreachable!(),
            },
            from_attrs: |a| Some(Op::AddEngine { w: a[0].u()? }),
            engine: Some(EngineSpec { io: w_io3, ..LANE_COST }),
            split_family: Some("split-add"),
            exemplar: "(add-engine 8)",
            exemplar_ty: X::Engine,
            ..base(OpKind::AddEngine, "add-engine", 0, C::Engine, sh_engine)
        },
        OpSpec {
            attrs: &[
                ("", A::U),
                ("", A::U),
                ("", A::U),
                ("", A::U),
                ("", A::U),
                ("", A::U),
                ("", A::U),
            ],
            attrs_of: |op| match op {
                Op::ConvEngine { oh, ow, c, k, kh, kw, stride } => vec![
                    AttrVal::U(*oh),
                    AttrVal::U(*ow),
                    AttrVal::U(*c),
                    AttrVal::U(*k),
                    AttrVal::U(*kh),
                    AttrVal::U(*kw),
                    AttrVal::U(*stride),
                ],
                _ => unreachable!(),
            },
            from_attrs: |a| {
                Some(Op::ConvEngine {
                    oh: a[0].u()?,
                    ow: a[1].u()?,
                    c: a[2].u()?,
                    k: a[3].u()?,
                    kh: a[4].u()?,
                    kw: a[5].u()?,
                    stride: a[6].u()?,
                })
            },
            engine: Some(CONV_COST),
            split_family: Some("split-conv"),
            exemplar: "(conv-engine 2 2 3 4 3 3 1)",
            exemplar_ty: X::Engine,
            ..base(OpKind::ConvEngine, "conv-engine", 0, C::Engine, sh_engine)
        },
        OpSpec {
            attrs: &[("", A::U), ("", A::U), ("", A::U), ("", A::U), ("", A::U), ("", A::U)],
            attrs_of: |op| match op {
                Op::PoolEngine { oh, ow, c, kh, kw, stride } => vec![
                    AttrVal::U(*oh),
                    AttrVal::U(*ow),
                    AttrVal::U(*c),
                    AttrVal::U(*kh),
                    AttrVal::U(*kw),
                    AttrVal::U(*stride),
                ],
                _ => unreachable!(),
            },
            from_attrs: |a| {
                Some(Op::PoolEngine {
                    oh: a[0].u()?,
                    ow: a[1].u()?,
                    c: a[2].u()?,
                    kh: a[3].u()?,
                    kw: a[4].u()?,
                    stride: a[5].u()?,
                })
            },
            engine: Some(POOL_COST),
            split_family: Some("split-pool"),
            exemplar: "(pool-engine 2 2 3 2 4 2)",
            exemplar_ty: X::Engine,
            ..base(OpKind::PoolEngine, "pool-engine", 0, C::Engine, sh_engine)
        },
        // ---- invocations --------------------------------------------------
        OpSpec {
            from_attrs: |_| Some(Op::InvokeMm),
            invoke_eval: Some(iv_mm),
            exemplar: "(invoke-mm (mm-engine 2 4 2) (input a [2 4]) (weight b [4 2]))",
            exemplar_ty: X::Tensor(&[2, 2]),
            ..base(OpKind::InvokeMm, "invoke-mm", 3, C::Invoke, sh_invoke_mm)
        },
        OpSpec {
            from_attrs: |_| Some(Op::InvokeMmRelu),
            invoke_eval: Some(iv_mm_relu),
            exemplar: "(invoke-mm-relu (mm-relu-engine 2 4 2) (input a [2 4]) (weight b [4 2]))",
            exemplar_ty: X::Tensor(&[2, 2]),
            ..base(OpKind::InvokeMmRelu, "invoke-mm-relu", 3, C::Invoke, sh_invoke_mm)
        },
        OpSpec {
            from_attrs: |_| Some(Op::InvokeRelu),
            invoke_eval: Some(iv_relu),
            exemplar: "(invoke-relu (relu-engine 8) (input x [8]))",
            exemplar_ty: X::Tensor(&[8]),
            ..base(OpKind::InvokeRelu, "invoke-relu", 2, C::Invoke, sh_invoke_elem)
        },
        OpSpec {
            from_attrs: |_| Some(Op::InvokeAdd),
            invoke_eval: Some(iv_add),
            exemplar: "(invoke-add (add-engine 4) (input x [4]) (input y [4]))",
            exemplar_ty: X::Tensor(&[4]),
            ..base(OpKind::InvokeAdd, "invoke-add", 3, C::Invoke, sh_invoke_add)
        },
        OpSpec {
            from_attrs: |_| Some(Op::InvokeConv),
            invoke_eval: Some(iv_conv),
            exemplar: "(invoke-conv (conv-engine 2 2 3 4 3 3 1) (input x [3 4 4]) (weight w [4 3 3 3]))",
            exemplar_ty: X::Tensor(&[4, 2, 2]),
            ..base(OpKind::InvokeConv, "invoke-conv", 3, C::Invoke, sh_invoke_conv)
        },
        OpSpec {
            from_attrs: |_| Some(Op::InvokePool),
            invoke_eval: Some(iv_pool),
            exemplar: "(invoke-pool (pool-engine 2 2 3 2 4 2) (input x [3 4 6]))",
            exemplar_ty: X::Tensor(&[3, 2, 2]),
            ..base(OpKind::InvokePool, "invoke-pool", 2, C::Invoke, sh_invoke_pool)
        },
        // ---- schedules ----------------------------------------------------
        OpSpec {
            attrs: &[("", A::Sym), ("a", A::U), ("x", A::U)],
            attrs_of: |op| match op {
                Op::SchedLoop { var, axis, extent } => {
                    vec![AttrVal::Sym(*var), AttrVal::U(*axis), AttrVal::U(*extent)]
                }
                _ => unreachable!(),
            },
            from_attrs: |a| {
                Some(Op::SchedLoop { var: a[0].sym()?, axis: a[1].u()?, extent: a[2].u()? })
            },
            exemplar: "(sched-loop i 0 2 (slice 0 2 (imul (lvar i) 2) (input x [4])))",
            exemplar_ty: X::Tensor(&[4]),
            ..base(OpKind::SchedLoop, "sched-loop", 1, C::Sched, sh_sched_map)
        },
        OpSpec {
            attrs: &[("", A::Sym), ("a", A::U), ("x", A::U)],
            attrs_of: |op| match op {
                Op::SchedPar { var, axis, extent } => {
                    vec![AttrVal::Sym(*var), AttrVal::U(*axis), AttrVal::U(*extent)]
                }
                _ => unreachable!(),
            },
            from_attrs: |a| {
                Some(Op::SchedPar { var: a[0].sym()?, axis: a[1].u()?, extent: a[2].u()? })
            },
            exemplar: "(sched-par i 0 2 (slice 0 2 (imul (lvar i) 2) (input x [4])))",
            exemplar_ty: X::Tensor(&[4]),
            ..base(OpKind::SchedPar, "sched-par", 1, C::Sched, sh_sched_map)
        },
        OpSpec {
            attrs: &[("", A::Sym), ("x", A::U)],
            attrs_of: |op| match op {
                Op::SchedReduce { var, extent } => {
                    vec![AttrVal::Sym(*var), AttrVal::U(*extent)]
                }
                _ => unreachable!(),
            },
            from_attrs: |a| Some(Op::SchedReduce { var: a[0].sym()?, extent: a[1].u()? }),
            exemplar: "(sched-reduce r 2 (slice 0 2 (imul (lvar r) 2) (input x [4])))",
            exemplar_ty: X::Tensor(&[2]),
            ..base(OpKind::SchedReduce, "sched-reduce", 1, C::Sched, sh_same)
        },
        // ---- data movement & storage -------------------------------------
        OpSpec {
            attrs: &[("a", A::U), ("l", A::U)],
            attrs_of: |op| match op {
                Op::SliceAx { axis, len } => vec![AttrVal::U(*axis), AttrVal::U(*len)],
                _ => unreachable!(),
            },
            from_attrs: |a| Some(Op::SliceAx { axis: a[0].u()?, len: a[1].u()? }),
            exemplar: "(slice 0 2 1 (input x [4]))",
            exemplar_ty: X::Tensor(&[2]),
            ..base(OpKind::SliceAx, "slice", 2, C::Data, sh_slice)
        },
        OpSpec {
            attrs: &[("", A::Sh)],
            attrs_of: |op| match op {
                Op::Reshape(sh) => vec![AttrVal::Sh(sh.clone())],
                _ => unreachable!(),
            },
            from_attrs: |a| Some(Op::Reshape(a[0].sh()?.clone())),
            eval: Some(ev_reshape),
            exemplar: "(reshape [2 2] (input x [4]))",
            exemplar_ty: X::Tensor(&[2, 2]),
            ..base(OpKind::Reshape, "reshape", 1, C::Data, sh_reshape)
        },
        OpSpec {
            attrs: &[("", A::Sh)],
            attrs_of: |op| match op {
                Op::Bcast(sh) => vec![AttrVal::Sh(sh.clone())],
                _ => unreachable!(),
            },
            from_attrs: |a| Some(Op::Bcast(a[0].sh()?.clone())),
            eval: Some(ev_bcast),
            exemplar: "(bcast [2 4] (input b [4]))",
            exemplar_ty: X::Tensor(&[2, 4]),
            ..base(OpKind::Bcast, "bcast", 1, C::Data, sh_bcast)
        },
        OpSpec {
            attrs: &[("ph", A::U), ("pw", A::U)],
            attrs_of: |op| match op {
                Op::Pad2d { pad_h, pad_w } => vec![AttrVal::U(*pad_h), AttrVal::U(*pad_w)],
                _ => unreachable!(),
            },
            from_attrs: |a| Some(Op::Pad2d { pad_h: a[0].u()?, pad_w: a[1].u()? }),
            eval: Some(ev_pad2d),
            data_traffic: true,
            exemplar: "(pad2d 2 2 (input x [1 2 2]))",
            exemplar_ty: X::Tensor(&[1, 4, 4]),
            ..base(OpKind::Pad2d, "pad2d", 1, C::Data, sh_pad2d)
        },
        OpSpec {
            attrs: &[("kh", A::U), ("kw", A::U), ("s", A::U)],
            attrs_of: |op| match op {
                Op::Im2Col { kh, kw, stride } => {
                    vec![AttrVal::U(*kh), AttrVal::U(*kw), AttrVal::U(*stride)]
                }
                _ => unreachable!(),
            },
            from_attrs: |a| {
                Some(Op::Im2Col { kh: a[0].u()?, kw: a[1].u()?, stride: a[2].u()? })
            },
            eval: Some(ev_im2col),
            data_traffic: true,
            exemplar: "(im2col 2 2 1 (input x [1 3 3]))",
            exemplar_ty: X::Tensor(&[4, 4]),
            ..base(OpKind::Im2Col, "im2col", 1, C::Data, sh_im2col)
        },
        OpSpec {
            attrs: &[("", A::Buf)],
            attrs_of: |op| match op {
                Op::Buffer { kind } => vec![AttrVal::Buf(*kind)],
                _ => unreachable!(),
            },
            from_attrs: |a| Some(Op::Buffer { kind: a[0].buf()? }),
            exemplar: "(buffer sram (input x [4]))",
            exemplar_ty: X::Tensor(&[4]),
            ..base(OpKind::Buffer, "buffer", 1, C::Storage, sh_same)
        },
        OpSpec {
            attrs: &[("", A::Buf)],
            attrs_of: |op| match op {
                Op::DblBuffer { kind } => vec![AttrVal::Buf(*kind)],
                _ => unreachable!(),
            },
            from_attrs: |a| Some(Op::DblBuffer { kind: a[0].buf()? }),
            exemplar: "(dbl-buffer dram (input x [4]))",
            exemplar_ty: X::Tensor(&[4]),
            ..base(OpKind::DblBuffer, "dbl-buffer", 1, C::Storage, sh_same)
        },
        // ---- transformer / depthwise extension ops -----------------------
        OpSpec {
            from_attrs: |_| Some(Op::Matmul),
            eval: Some(ev_matmul),
            lower: Some(lo_mm),
            host_work: Some(hw_mm),
            exemplar: "(matmul (input a [2 8]) (input b [8 4]))",
            exemplar_ty: X::Tensor(&[2, 4]),
            ..base(OpKind::Matmul, "matmul", 2, C::Relay, sh_dense)
        },
        OpSpec {
            from_attrs: |_| Some(Op::BatchMatmul),
            eval: Some(ev_bmm),
            lower: Some(lo_bmm),
            host_work: Some(hw_bmm),
            exemplar: "(batch-matmul (input a [2 3 4]) (input b [2 4 5]))",
            exemplar_ty: X::Tensor(&[2, 3, 5]),
            ..base(OpKind::BatchMatmul, "batch-matmul", 2, C::Relay, sh_bmm)
        },
        OpSpec {
            from_attrs: |_| Some(Op::Transpose),
            eval: Some(ev_transpose),
            data_traffic: true,
            exemplar: "(transpose (input x [2 3]))",
            exemplar_ty: X::Tensor(&[3, 2]),
            ..base(OpKind::Transpose, "transpose", 1, C::Data, sh_transpose)
        },
        OpSpec {
            from_attrs: |_| Some(Op::Softmax),
            eval: Some(ev_softmax),
            lower: Some(lo_softmax),
            host_work: Some(hw_rowwise),
            exemplar: "(softmax (input x [2 4]))",
            exemplar_ty: X::Tensor(&[2, 4]),
            ..base(OpKind::Softmax, "softmax", 1, C::Relay, sh_rowwise)
        },
        OpSpec {
            from_attrs: |_| Some(Op::LayerNorm),
            eval: Some(ev_layernorm),
            lower: Some(lo_layernorm),
            host_work: Some(hw_rowwise),
            exemplar: "(layernorm (input x [2 4]) (weight g [4]) (weight b [4]))",
            exemplar_ty: X::Tensor(&[2, 4]),
            ..base(OpKind::LayerNorm, "layernorm", 3, C::Relay, sh_layernorm)
        },
        OpSpec {
            from_attrs: |_| Some(Op::Gelu),
            eval: Some(ev_gelu),
            lower: Some(lo_gelu),
            exemplar: "(gelu (input x [8]))",
            exemplar_ty: X::Tensor(&[8]),
            ..base(OpKind::Gelu, "gelu", 1, C::Relay, sh_same)
        },
        OpSpec {
            attrs: &[("s", A::U), ("ph", A::U), ("pw", A::U)],
            attrs_of: |op| match op {
                Op::DepthwiseConv2d { stride, pad_h, pad_w } => {
                    vec![AttrVal::U(*stride), AttrVal::U(*pad_h), AttrVal::U(*pad_w)]
                }
                _ => unreachable!(),
            },
            from_attrs: |a| {
                Some(Op::DepthwiseConv2d {
                    stride: a[0].u()?,
                    pad_h: a[1].u()?,
                    pad_w: a[2].u()?,
                })
            },
            eval: Some(ev_dwconv),
            lower: Some(lo_dwconv),
            host_work: Some(hw_dwconv),
            exemplar: "(dwconv2d 1 2 2 (input x [3 8 8]) (weight w [3 3 3]))",
            exemplar_ty: X::Tensor(&[3, 8, 8]),
            ..base(OpKind::DepthwiseConv2d, "dwconv2d", 2, C::Relay, sh_dwconv2d)
        },
        OpSpec {
            attrs: &[("", A::U)],
            attrs_of: |op| match op {
                Op::SoftmaxEngine { w } => vec![AttrVal::U(*w)],
                _ => unreachable!(),
            },
            from_attrs: |a| Some(Op::SoftmaxEngine { w: a[0].u()? }),
            engine: Some(ROW_COST),
            // split_family: None — normalization couples the whole row, so
            // the softmax engine has no width split (documented exemption).
            exemplar: "(softmax-engine 8)",
            exemplar_ty: X::Engine,
            ..base(OpKind::SoftmaxEngine, "softmax-engine", 0, C::Engine, sh_engine)
        },
        OpSpec {
            attrs: &[("", A::U)],
            attrs_of: |op| match op {
                Op::LayerNormEngine { w } => vec![AttrVal::U(*w)],
                _ => unreachable!(),
            },
            from_attrs: |a| Some(Op::LayerNormEngine { w: a[0].u()? }),
            engine: Some(ROW_COST),
            // split_family: None — same row coupling as softmax (exempt).
            exemplar: "(layernorm-engine 8)",
            exemplar_ty: X::Engine,
            ..base(OpKind::LayerNormEngine, "layernorm-engine", 0, C::Engine, sh_engine)
        },
        OpSpec {
            attrs: &[("", A::U)],
            attrs_of: |op| match op {
                Op::GeluEngine { w } => vec![AttrVal::U(*w)],
                _ => unreachable!(),
            },
            from_attrs: |a| Some(Op::GeluEngine { w: a[0].u()? }),
            engine: Some(LANE_COST),
            split_family: Some("split-gelu"),
            exemplar: "(gelu-engine 8)",
            exemplar_ty: X::Engine,
            ..base(OpKind::GeluEngine, "gelu-engine", 0, C::Engine, sh_engine)
        },
        OpSpec {
            attrs: &[("", A::U), ("", A::U), ("", A::U), ("", A::U), ("", A::U), ("", A::U)],
            attrs_of: |op| match op {
                Op::DwConvEngine { oh, ow, c, kh, kw, stride } => vec![
                    AttrVal::U(*oh),
                    AttrVal::U(*ow),
                    AttrVal::U(*c),
                    AttrVal::U(*kh),
                    AttrVal::U(*kw),
                    AttrVal::U(*stride),
                ],
                _ => unreachable!(),
            },
            from_attrs: |a| {
                Some(Op::DwConvEngine {
                    oh: a[0].u()?,
                    ow: a[1].u()?,
                    c: a[2].u()?,
                    kh: a[3].u()?,
                    kw: a[4].u()?,
                    stride: a[5].u()?,
                })
            },
            engine: Some(DWCONV_COST),
            split_family: Some("split-dwconv"),
            exemplar: "(dw-conv-engine 2 2 3 3 3 1)",
            exemplar_ty: X::Engine,
            ..base(OpKind::DwConvEngine, "dw-conv-engine", 0, C::Engine, sh_engine)
        },
        OpSpec {
            from_attrs: |_| Some(Op::InvokeSoftmax),
            invoke_eval: Some(iv_softmax),
            exemplar: "(invoke-softmax (softmax-engine 4) (input x [4]))",
            exemplar_ty: X::Tensor(&[4]),
            ..base(OpKind::InvokeSoftmax, "invoke-softmax", 2, C::Invoke, sh_invoke_elem)
        },
        OpSpec {
            from_attrs: |_| Some(Op::InvokeLayerNorm),
            invoke_eval: Some(iv_layernorm),
            exemplar: "(invoke-layernorm (layernorm-engine 4) (input x [4]))",
            exemplar_ty: X::Tensor(&[4]),
            ..base(OpKind::InvokeLayerNorm, "invoke-layernorm", 2, C::Invoke, sh_invoke_elem)
        },
        OpSpec {
            from_attrs: |_| Some(Op::InvokeGelu),
            invoke_eval: Some(iv_gelu),
            exemplar: "(invoke-gelu (gelu-engine 4) (input x [4]))",
            exemplar_ty: X::Tensor(&[4]),
            ..base(OpKind::InvokeGelu, "invoke-gelu", 2, C::Invoke, sh_invoke_elem)
        },
        OpSpec {
            from_attrs: |_| Some(Op::InvokeDwConv),
            invoke_eval: Some(iv_dwconv),
            exemplar: "(invoke-dw-conv (dw-conv-engine 2 2 3 3 3 1) (input x [3 4 4]) (weight w [3 3 3]))",
            exemplar_ty: X::Tensor(&[3, 2, 2]),
            ..base(OpKind::InvokeDwConv, "invoke-dw-conv", 3, C::Invoke, sh_invoke_dwconv)
        },
        // ---- elementwise multiply (affine layernorm's scale path) --------
        OpSpec {
            from_attrs: |_| Some(Op::Emul),
            eval: Some(ev_emul),
            lower: Some(lo_emul),
            exemplar: "(emul (input x [4]) (input y [4]))",
            exemplar_ty: X::Tensor(&[4]),
            ..base(OpKind::Emul, "emul", 2, C::Relay, sh_eadd)
        },
        OpSpec {
            attrs: &[("", A::U)],
            attrs_of: |op| match op {
                Op::EmulEngine { w } => vec![AttrVal::U(*w)],
                _ => unreachable!(),
            },
            from_attrs: |a| Some(Op::EmulEngine { w: a[0].u()? }),
            engine: Some(EngineSpec { io: w_io3, ..LANE_COST }),
            split_family: Some("split-emul"),
            exemplar: "(emul-engine 8)",
            exemplar_ty: X::Engine,
            ..base(OpKind::EmulEngine, "emul-engine", 0, C::Engine, sh_engine)
        },
        OpSpec {
            from_attrs: |_| Some(Op::InvokeEmul),
            invoke_eval: Some(iv_emul),
            exemplar: "(invoke-emul (emul-engine 4) (input x [4]) (input y [4]))",
            exemplar_ty: X::Tensor(&[4]),
            ..base(OpKind::InvokeEmul, "invoke-emul", 3, C::Invoke, sh_invoke_add)
        },
        // ---- inline constant tensors (imported initializers) --------------
        OpSpec {
            attrs: &[("", A::Sh), ("", A::F32s)],
            attrs_of: |op| match op {
                Op::Constant(c) => {
                    vec![AttrVal::Sh(c.shape().clone()), AttrVal::F32s(c.values())]
                }
                _ => unreachable!(),
            },
            from_attrs: |a| {
                let sh = a[0].sh()?.clone();
                let vals = a[1].f32s()?;
                if sh.numel() != vals.len() {
                    return None;
                }
                Some(Op::Constant(ConstData::new(sh, vals)))
            },
            exemplar: "(const [2] [1.5 -0.25])",
            exemplar_ty: X::Tensor(&[2]),
            ..base(OpKind::Constant, "const", 0, C::Leaf, sh_const)
        },
    ]
}

/// The registry: specs indexed by `OpKind` discriminant plus a head-name
/// index for the parser.
pub struct Registry {
    specs: Vec<OpSpec>,
    by_name: HashMap<&'static str, OpKind>,
}

static REGISTRY: OnceLock<Registry> = OnceLock::new();

fn registry() -> &'static Registry {
    REGISTRY.get_or_init(|| {
        let specs = build_specs();
        assert_eq!(specs.len(), OpKind::ALL.len(), "registry incomplete");
        let mut by_name = HashMap::new();
        for (i, s) in specs.iter().enumerate() {
            assert_eq!(
                s.kind as usize, i,
                "registry order mismatch at {i}: {:?}",
                s.kind
            );
            assert!(
                by_name.insert(s.name, s.kind).is_none(),
                "duplicate head name '{}'",
                s.name
            );
        }
        Registry { specs, by_name }
    })
}

/// The spec for a kind (O(1) array index).
pub fn of(kind: OpKind) -> &'static OpSpec {
    &registry().specs[kind as usize]
}

/// Parser-side lookup by s-expression head name.
pub fn by_name(name: &str) -> Option<&'static OpSpec> {
    registry().by_name.get(name).map(|&k| of(k))
}

/// All specs in registry order (for exhaustive tests).
pub fn all_specs() -> &'static [OpSpec] {
    &registry().specs
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Every class-mandated field is populated — an op cannot be registered
    /// half-wired.
    #[test]
    fn registry_internally_consistent() {
        for s in all_specs() {
            assert!(!s.exemplar.is_empty(), "{:?}: missing exemplar", s.kind);
            match s.class {
                C::Relay => {
                    assert!(s.eval.is_some(), "{:?}: relay op without eval kernel", s.kind);
                }
                C::Engine => {
                    assert!(s.engine.is_some(), "{:?}: engine without cost spec", s.kind);
                    assert_eq!(s.arity, 0, "{:?}: engines are leaves", s.kind);
                }
                C::Invoke => {
                    assert!(s.invoke_eval.is_some(), "{:?}: invoke without kernel", s.kind);
                }
                _ => {}
            }
        }
    }

    #[test]
    fn lookup_by_kind_and_name_agree() {
        for &k in OpKind::ALL {
            let s = of(k);
            assert_eq!(s.kind, k);
            assert_eq!(by_name(s.name).unwrap().kind, k);
        }
        assert!(by_name("frobnicate").is_none());
    }

    #[test]
    fn engine_merge_is_elementwise_max() {
        let a = Op::ConvEngine { oh: 2, ow: 8, c: 3, k: 4, kh: 3, kw: 1, stride: 1 };
        let b = Op::ConvEngine { oh: 4, ow: 2, c: 3, k: 8, kh: 1, kw: 3, stride: 1 };
        let m = (of(OpKind::ConvEngine).engine.unwrap().merge_max)(&a, &b);
        assert_eq!(
            m,
            Op::ConvEngine { oh: 4, ow: 8, c: 3, k: 8, kh: 3, kw: 3, stride: 1 }
        );
    }
}

