//! Shapes and the EngineIR type system.
//!
//! Every e-class carries a [`Ty`] computed by the e-graph's analysis: an
//! integer index expression, a tensor of static shape, or a hardware engine
//! signature. Rewrites are *shape-preserving by construction*, and the
//! analysis double-checks this: a [`TypeError`] on `union` indicates a
//! broken rewrite (this is exercised heavily by the differential tests).

use super::op::Op;
use std::fmt;

/// A static tensor shape (row-major, element type f32 throughout).
#[derive(Clone, PartialEq, Eq, Hash, Debug, PartialOrd, Ord)]
pub struct Shape(pub Vec<usize>);

impl Shape {
    pub fn new(dims: &[usize]) -> Self {
        Shape(dims.to_vec())
    }

    /// Number of dimensions.
    pub fn rank(&self) -> usize {
        self.0.len()
    }

    /// Total element count.
    pub fn numel(&self) -> usize {
        self.0.iter().product()
    }

    /// Size along `axis`.
    pub fn dim(&self, axis: usize) -> usize {
        self.0[axis]
    }

    /// Copy with `axis` set to `len`.
    pub fn with_dim(&self, axis: usize, len: usize) -> Shape {
        let mut d = self.0.clone();
        d[axis] = len;
        Shape(d)
    }
}

impl fmt::Display for Shape {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "[")?;
        for (i, d) in self.0.iter().enumerate() {
            if i > 0 {
                write!(f, ",")?;
            }
            write!(f, "{d}")?;
        }
        write!(f, "]")
    }
}

/// Signature of a hardware engine declaration: the op itself (parameters are
/// data on the op, so the op *is* the signature).
#[derive(Clone, PartialEq, Eq, Hash, Debug)]
pub struct EngineSig(pub Op);

/// The type of an EngineIR e-class.
#[derive(Clone, PartialEq, Eq, Hash, Debug)]
pub enum Ty {
    /// Integer index expression (slice starts, loop arithmetic).
    Index,
    /// Tensor with static shape.
    Tensor(Shape),
    /// Hardware engine declaration.
    Engine(EngineSig),
}

impl Ty {
    /// Shape if this is a tensor type.
    pub fn shape(&self) -> Option<&Shape> {
        match self {
            Ty::Tensor(s) => Some(s),
            _ => None,
        }
    }

    /// Engine op if this is an engine type.
    pub fn engine(&self) -> Option<&Op> {
        match self {
            Ty::Engine(EngineSig(op)) => Some(op),
            _ => None,
        }
    }
}

/// A shape/type inference failure.
#[derive(Debug, Clone)]
pub enum TypeError {
    Arity { op: String, expected: usize, got: usize },
    Child { op: String, child: usize, got: Ty, expected: String },
    Shape { op: String, msg: String },
    Merge { a: Ty, b: Ty },
}

impl std::fmt::Display for TypeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TypeError::Arity { op, expected, got } => {
                write!(f, "op {op} expected {expected} children, got {got}")
            }
            TypeError::Child { op, child, got, expected } => {
                write!(f, "op {op}: child {child} has type {got:?}, expected {expected}")
            }
            TypeError::Shape { op, msg } => write!(f, "op {op}: shape mismatch: {msg}"),
            TypeError::Merge { a, b } => {
                write!(f, "union merged incompatible types {a:?} and {b:?}")
            }
        }
    }
}

impl std::error::Error for TypeError {}

fn tensor<'a>(op: &Op, i: usize, tys: &[&'a Ty]) -> Result<&'a Shape, TypeError> {
    tys[i].shape().ok_or_else(|| TypeError::Child {
        op: op.to_string(),
        child: i,
        got: tys[i].clone(),
        expected: "tensor".into(),
    })
}

fn index(op: &Op, i: usize, tys: &[&Ty]) -> Result<(), TypeError> {
    if matches!(tys[i], &Ty::Index) {
        Ok(())
    } else {
        Err(TypeError::Child {
            op: op.to_string(),
            child: i,
            got: tys[i].clone(),
            expected: "index".into(),
        })
    }
}

fn engine<'a>(op: &Op, i: usize, tys: &[&'a Ty]) -> Result<&'a Op, TypeError> {
    tys[i].engine().ok_or_else(|| TypeError::Child {
        op: op.to_string(),
        child: i,
        got: tys[i].clone(),
        expected: "engine".into(),
    })
}

fn shape_err(op: &Op, msg: impl Into<String>) -> TypeError {
    TypeError::Shape { op: op.to_string(), msg: msg.into() }
}

/// Output tile side for a valid (pre-padded) convolution/pool window sweep.
pub fn out_dim(i: usize, k: usize, stride: usize) -> Option<usize> {
    if i < k {
        return None;
    }
    if (i - k) % stride != 0 {
        return None;
    }
    Some((i - k) / stride + 1)
}

/// Input tile side needed to produce `o` outputs with window `k`, `stride`.
pub fn in_dim(o: usize, k: usize, stride: usize) -> usize {
    (o - 1) * stride + k
}

/// Infer the type of `op` given its children's types. This is the single
/// source of truth for EngineIR's static semantics.
pub fn infer(op: &Op, tys: &[Ty]) -> Result<Ty, TypeError> {
    let refs: Vec<&Ty> = tys.iter().collect();
    infer_ref(op, &refs)
}

/// By-reference variant of [`infer`] — the e-graph hot path uses this to
/// avoid cloning child types (shapes allocate) on every node insertion.
pub fn infer_ref(op: &Op, tys: &[&Ty]) -> Result<Ty, TypeError> {
    if let Some(a) = op.arity() {
        if tys.len() != a {
            return Err(TypeError::Arity { op: op.to_string(), expected: a, got: tys.len() });
        }
    }
    match op {
        Op::Int(_) | Op::LVar(_) => Ok(Ty::Index),
        Op::IMul | Op::IAdd => {
            index(op, 0, tys)?;
            index(op, 1, tys)?;
            Ok(Ty::Index)
        }
        Op::Input(_, sh) | Op::Weight(_, sh) => Ok(Ty::Tensor(sh.clone())),

        // ---- Relay level ----
        Op::Conv2d { stride, pad } => {
            let x = tensor(op, 0, tys)?;
            let w = tensor(op, 1, tys)?;
            if x.rank() != 3 || w.rank() != 4 {
                return Err(shape_err(op, format!("want x rank 3, w rank 4; got {x} {w}")));
            }
            let (c, h, wd) = (x.dim(0), x.dim(1), x.dim(2));
            let (kout, cin, kh, kw) = (w.dim(0), w.dim(1), w.dim(2), w.dim(3));
            if cin != c || kh != kw {
                return Err(shape_err(op, format!("channels/kernel mismatch: x{x} w{w}")));
            }
            let oh = out_dim(h + 2 * pad, kh, *stride)
                .ok_or_else(|| shape_err(op, "H does not tile"))?;
            let ow = out_dim(wd + 2 * pad, kw, *stride)
                .ok_or_else(|| shape_err(op, "W does not tile"))?;
            Ok(Ty::Tensor(Shape::new(&[kout, oh, ow])))
        }
        Op::Dense => {
            let x = tensor(op, 0, tys)?;
            let w = tensor(op, 1, tys)?;
            if x.rank() != 2 || w.rank() != 2 || x.dim(1) != w.dim(0) {
                return Err(shape_err(op, format!("dense shapes x{x} w{w}")));
            }
            Ok(Ty::Tensor(Shape::new(&[x.dim(0), w.dim(1)])))
        }
        Op::Relu => Ok(Ty::Tensor(tensor(op, 0, tys)?.clone())),
        Op::BiasAdd => {
            let x = tensor(op, 0, tys)?;
            let b = tensor(op, 1, tys)?;
            if b.rank() != 1 {
                return Err(shape_err(op, format!("bias must be rank 1, got {b}")));
            }
            let want = match x.rank() {
                3 => x.dim(0),
                2 => x.dim(1),
                _ => return Err(shape_err(op, format!("bias-add on rank {}", x.rank()))),
            };
            if b.dim(0) != want {
                return Err(shape_err(op, format!("bias {b} vs x {x}")));
            }
            Ok(Ty::Tensor(x.clone()))
        }
        Op::EAdd => {
            let x = tensor(op, 0, tys)?;
            let y = tensor(op, 1, tys)?;
            if x != y {
                return Err(shape_err(op, format!("eadd {x} vs {y}")));
            }
            Ok(Ty::Tensor(x.clone()))
        }
        Op::MaxPool2d { k, stride } => {
            let x = tensor(op, 0, tys)?;
            if x.rank() != 3 {
                return Err(shape_err(op, format!("maxpool on {x}")));
            }
            let oh =
                out_dim(x.dim(1), *k, *stride).ok_or_else(|| shape_err(op, "H does not tile"))?;
            let ow =
                out_dim(x.dim(2), *k, *stride).ok_or_else(|| shape_err(op, "W does not tile"))?;
            Ok(Ty::Tensor(Shape::new(&[x.dim(0), oh, ow])))
        }
        Op::Flatten => {
            let x = tensor(op, 0, tys)?;
            Ok(Ty::Tensor(Shape::new(&[1, x.numel()])))
        }
        Op::GlobalAvgPool => {
            let x = tensor(op, 0, tys)?;
            if x.rank() != 3 {
                return Err(shape_err(op, format!("gap on {x}")));
            }
            Ok(Ty::Tensor(Shape::new(&[x.dim(0)])))
        }

        // ---- engines ----
        Op::MmEngine { .. }
        | Op::MmReluEngine { .. }
        | Op::ReluEngine { .. }
        | Op::AddEngine { .. }
        | Op::ConvEngine { .. }
        | Op::PoolEngine { .. } => Ok(Ty::Engine(EngineSig(op.clone()))),

        // ---- invocations ----
        Op::InvokeMm | Op::InvokeMmRelu => {
            let e = engine(op, 0, tys)?;
            let (m, k, n) = match (op, e) {
                (Op::InvokeMm, Op::MmEngine { m, k, n }) => (*m, *k, *n),
                (Op::InvokeMmRelu, Op::MmReluEngine { m, k, n }) => (*m, *k, *n),
                _ => return Err(shape_err(op, format!("wrong engine {e}"))),
            };
            let a = tensor(op, 1, tys)?;
            let b = tensor(op, 2, tys)?;
            if a != &Shape::new(&[m, k]) || b != &Shape::new(&[k, n]) {
                return Err(shape_err(op, format!("mm({m},{k},{n}) got a{a} b{b}")));
            }
            Ok(Ty::Tensor(Shape::new(&[m, n])))
        }
        Op::InvokeRelu => {
            let e = engine(op, 0, tys)?;
            let w = match e {
                Op::ReluEngine { w } => *w,
                _ => return Err(shape_err(op, format!("wrong engine {e}"))),
            };
            let x = tensor(op, 1, tys)?;
            if x != &Shape::new(&[w]) {
                return Err(shape_err(op, format!("relu({w}) got {x}")));
            }
            Ok(Ty::Tensor(x.clone()))
        }
        Op::InvokeAdd => {
            let e = engine(op, 0, tys)?;
            let w = match e {
                Op::AddEngine { w } => *w,
                _ => return Err(shape_err(op, format!("wrong engine {e}"))),
            };
            let x = tensor(op, 1, tys)?;
            let y = tensor(op, 2, tys)?;
            if x != &Shape::new(&[w]) || y != &Shape::new(&[w]) {
                return Err(shape_err(op, format!("add({w}) got {x} {y}")));
            }
            Ok(Ty::Tensor(x.clone()))
        }
        Op::InvokeConv => {
            let e = engine(op, 0, tys)?;
            let (oh, ow, c, k, kh, stride) = match e {
                Op::ConvEngine { oh, ow, c, k, kh, stride } => (*oh, *ow, *c, *k, *kh, *stride),
                _ => return Err(shape_err(op, format!("wrong engine {e}"))),
            };
            let x = tensor(op, 1, tys)?;
            let w = tensor(op, 2, tys)?;
            let want_x = Shape::new(&[c, in_dim(oh, kh, stride), in_dim(ow, kh, stride)]);
            let want_w = Shape::new(&[k, c, kh, kh]);
            if x != &want_x || w != &want_w {
                return Err(shape_err(
                    op,
                    format!("conv engine wants x{want_x} w{want_w}; got x{x} w{w}"),
                ));
            }
            Ok(Ty::Tensor(Shape::new(&[k, oh, ow])))
        }
        Op::InvokePool => {
            let e = engine(op, 0, tys)?;
            let (oh, ow, c, k, stride) = match e {
                Op::PoolEngine { oh, ow, c, k, stride } => (*oh, *ow, *c, *k, *stride),
                _ => return Err(shape_err(op, format!("wrong engine {e}"))),
            };
            let x = tensor(op, 1, tys)?;
            let want = Shape::new(&[c, in_dim(oh, k, stride), in_dim(ow, k, stride)]);
            if x != &want {
                return Err(shape_err(op, format!("pool engine wants {want}; got {x}")));
            }
            Ok(Ty::Tensor(Shape::new(&[c, oh, ow])))
        }

        // ---- schedules ----
        Op::SchedLoop { axis, extent, .. } | Op::SchedPar { axis, extent, .. } => {
            let b = tensor(op, 0, tys)?;
            if *axis >= b.rank() {
                return Err(shape_err(op, format!("axis {axis} out of range for {b}")));
            }
            Ok(Ty::Tensor(b.with_dim(*axis, b.dim(*axis) * extent)))
        }
        Op::SchedReduce { .. } => Ok(Ty::Tensor(tensor(op, 0, tys)?.clone())),

        // ---- data movement / storage ----
        Op::SliceAx { axis, len } => {
            index(op, 0, tys)?;
            let x = tensor(op, 1, tys)?;
            if *axis >= x.rank() || *len > x.dim(*axis) {
                return Err(shape_err(op, format!("slice a{axis} l{len} of {x}")));
            }
            Ok(Ty::Tensor(x.with_dim(*axis, *len)))
        }
        Op::Reshape(sh) => {
            let x = tensor(op, 0, tys)?;
            if x.numel() != sh.numel() {
                return Err(shape_err(op, format!("reshape {x} -> {sh}")));
            }
            Ok(Ty::Tensor(sh.clone()))
        }
        Op::Bcast(sh) => {
            let b = tensor(op, 0, tys)?;
            if b.rank() != 1 {
                return Err(shape_err(op, format!("bcast of rank {}", b.rank())));
            }
            let ok = match sh.rank() {
                3 => sh.dim(0) == b.dim(0),
                2 => sh.dim(1) == b.dim(0),
                1 => sh.dim(0) == b.dim(0),
                _ => false,
            };
            if !ok {
                return Err(shape_err(op, format!("bcast {b} -> {sh}")));
            }
            Ok(Ty::Tensor(sh.clone()))
        }
        Op::Pad2d { pad } => {
            let x = tensor(op, 0, tys)?;
            if x.rank() != 3 {
                return Err(shape_err(op, format!("pad2d on {x}")));
            }
            Ok(Ty::Tensor(Shape::new(&[x.dim(0), x.dim(1) + 2 * pad, x.dim(2) + 2 * pad])))
        }
        Op::Im2Col { kh, stride } => {
            let x = tensor(op, 0, tys)?;
            if x.rank() != 3 {
                return Err(shape_err(op, format!("im2col on {x}")));
            }
            let oh = out_dim(x.dim(1), *kh, *stride)
                .ok_or_else(|| shape_err(op, "H does not tile"))?;
            let ow = out_dim(x.dim(2), *kh, *stride)
                .ok_or_else(|| shape_err(op, "W does not tile"))?;
            Ok(Ty::Tensor(Shape::new(&[x.dim(0) * kh * kh, oh * ow])))
        }
        Op::Buffer { .. } | Op::DblBuffer { .. } => Ok(Ty::Tensor(tensor(op, 0, tys)?.clone())),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::Symbol;

    fn t(dims: &[usize]) -> Ty {
        Ty::Tensor(Shape::new(dims))
    }

    #[test]
    fn conv2d_shape() {
        let ty = infer(
            &Op::Conv2d { stride: 1, pad: 1 },
            &[t(&[3, 32, 32]), t(&[8, 3, 3, 3])],
        )
        .unwrap();
        assert_eq!(ty, t(&[8, 32, 32]));
    }

    #[test]
    fn conv2d_stride2() {
        let ty = infer(
            &Op::Conv2d { stride: 2, pad: 0 },
            &[t(&[3, 33, 33]), t(&[8, 3, 3, 3])],
        )
        .unwrap();
        assert_eq!(ty, t(&[8, 16, 16]));
    }

    #[test]
    fn conv2d_rejects_channel_mismatch() {
        assert!(infer(
            &Op::Conv2d { stride: 1, pad: 0 },
            &[t(&[4, 8, 8]), t(&[8, 3, 3, 3])]
        )
        .is_err());
    }

    #[test]
    fn dense_shape() {
        assert_eq!(infer(&Op::Dense, &[t(&[1, 784]), t(&[784, 128])]).unwrap(), t(&[1, 128]));
        assert!(infer(&Op::Dense, &[t(&[1, 784]), t(&[783, 128])]).is_err());
    }

    #[test]
    fn invoke_mm_checks_engine_params() {
        let e = Ty::Engine(EngineSig(Op::MmEngine { m: 4, k: 8, n: 2 }));
        assert_eq!(
            infer(&Op::InvokeMm, &[e.clone(), t(&[4, 8]), t(&[8, 2])]).unwrap(),
            t(&[4, 2])
        );
        assert!(infer(&Op::InvokeMm, &[e, t(&[4, 8]), t(&[8, 3])]).is_err());
    }

    #[test]
    fn invoke_conv_halo_shape() {
        // 2x4 output tile, 3x3 kernel, stride 1 -> needs (2-1)+3 = 4 rows in.
        let e = Ty::Engine(EngineSig(Op::ConvEngine {
            oh: 2,
            ow: 4,
            c: 3,
            k: 8,
            kh: 3,
            stride: 1,
        }));
        let ty = infer(&Op::InvokeConv, &[e, t(&[3, 4, 6]), t(&[8, 3, 3, 3])]).unwrap();
        assert_eq!(ty, t(&[8, 2, 4]));
    }

    #[test]
    fn sched_loop_multiplies_axis() {
        let v = Symbol::new("i");
        let ty =
            infer(&Op::SchedLoop { var: v, axis: 1, extent: 4 }, &[t(&[8, 2, 4])]).unwrap();
        assert_eq!(ty, t(&[8, 8, 4]));
    }

    #[test]
    fn slice_keeps_static_shape_with_dynamic_start() {
        let ty = infer(&Op::SliceAx { axis: 1, len: 16 }, &[Ty::Index, t(&[3, 32, 32])]).unwrap();
        assert_eq!(ty, t(&[3, 16, 32]));
    }

    #[test]
    fn reshape_checks_numel() {
        assert!(infer(&Op::Reshape(Shape::new(&[2, 8])), &[t(&[4, 4])]).is_ok());
        assert!(infer(&Op::Reshape(Shape::new(&[2, 9])), &[t(&[4, 4])]).is_err());
    }

    #[test]
    fn im2col_shape() {
        // (3,32,32) with 3x3 stride 1 -> (27, 900)
        let ty = infer(&Op::Im2Col { kh: 3, stride: 1 }, &[t(&[3, 32, 32])]).unwrap();
        assert_eq!(ty, t(&[27, 900]));
    }

    #[test]
    fn out_in_dims_roundtrip() {
        for stride in 1..4 {
            for k in 1..5 {
                for o in 1..10 {
                    let i = in_dim(o, k, stride);
                    assert_eq!(out_dim(i, k, stride), Some(o));
                }
            }
        }
    }
}
