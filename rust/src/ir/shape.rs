//! Shapes and the EngineIR type system.
//!
//! Every e-class carries a [`Ty`] computed by the e-graph's analysis: an
//! integer index expression, a tensor of static shape, or a hardware engine
//! signature. Rewrites are *shape-preserving by construction*, and the
//! analysis double-checks this: a [`TypeError`] on `union` indicates a
//! broken rewrite (this is exercised heavily by the differential tests).
//!
//! The per-op shape rules live in each op's [`crate::ir::spec::OpSpec`]
//! entry; [`infer_ref`] checks arity and dispatches through the registry.

use super::op::Op;
use std::fmt;

/// A static tensor shape (row-major, element type f32 throughout).
#[derive(Clone, PartialEq, Eq, Hash, Debug, PartialOrd, Ord)]
pub struct Shape(pub Vec<usize>);

impl Shape {
    pub fn new(dims: &[usize]) -> Self {
        Shape(dims.to_vec())
    }

    /// Number of dimensions.
    pub fn rank(&self) -> usize {
        self.0.len()
    }

    /// Total element count.
    pub fn numel(&self) -> usize {
        self.0.iter().product()
    }

    /// Size along `axis`.
    pub fn dim(&self, axis: usize) -> usize {
        self.0[axis]
    }

    /// Copy with `axis` set to `len`.
    pub fn with_dim(&self, axis: usize, len: usize) -> Shape {
        let mut d = self.0.clone();
        d[axis] = len;
        Shape(d)
    }
}

impl fmt::Display for Shape {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "[")?;
        for (i, d) in self.0.iter().enumerate() {
            if i > 0 {
                write!(f, ",")?;
            }
            write!(f, "{d}")?;
        }
        write!(f, "]")
    }
}

/// Signature of a hardware engine declaration: the op itself (parameters are
/// data on the op, so the op *is* the signature).
#[derive(Clone, PartialEq, Eq, Hash, Debug)]
pub struct EngineSig(pub Op);

/// The type of an EngineIR e-class.
#[derive(Clone, PartialEq, Eq, Hash, Debug)]
pub enum Ty {
    /// Integer index expression (slice starts, loop arithmetic).
    Index,
    /// Tensor with static shape.
    Tensor(Shape),
    /// Hardware engine declaration.
    Engine(EngineSig),
}

impl Ty {
    /// Shape if this is a tensor type.
    pub fn shape(&self) -> Option<&Shape> {
        match self {
            Ty::Tensor(s) => Some(s),
            _ => None,
        }
    }

    /// Engine op if this is an engine type.
    pub fn engine(&self) -> Option<&Op> {
        match self {
            Ty::Engine(EngineSig(op)) => Some(op),
            _ => None,
        }
    }
}

/// A shape/type inference failure.
#[derive(Debug, Clone)]
pub enum TypeError {
    Arity { op: String, expected: usize, got: usize },
    Child { op: String, child: usize, got: Ty, expected: String },
    Shape { op: String, msg: String },
    Merge { a: Ty, b: Ty },
}

impl std::fmt::Display for TypeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TypeError::Arity { op, expected, got } => {
                write!(f, "op {op} expected {expected} children, got {got}")
            }
            TypeError::Child { op, child, got, expected } => {
                write!(f, "op {op}: child {child} has type {got:?}, expected {expected}")
            }
            TypeError::Shape { op, msg } => write!(f, "op {op}: shape mismatch: {msg}"),
            TypeError::Merge { a, b } => {
                write!(f, "union merged incompatible types {a:?} and {b:?}")
            }
        }
    }
}

impl std::error::Error for TypeError {}

/// Child `i` must be a tensor (shape-rule helper for registry entries).
pub(crate) fn tensor<'a>(op: &Op, i: usize, tys: &[&'a Ty]) -> Result<&'a Shape, TypeError> {
    tys[i].shape().ok_or_else(|| TypeError::Child {
        op: op.to_string(),
        child: i,
        got: tys[i].clone(),
        expected: "tensor".into(),
    })
}

/// Child `i` must be an index expression.
pub(crate) fn index(op: &Op, i: usize, tys: &[&Ty]) -> Result<(), TypeError> {
    if matches!(tys[i], &Ty::Index) {
        Ok(())
    } else {
        Err(TypeError::Child {
            op: op.to_string(),
            child: i,
            got: tys[i].clone(),
            expected: "index".into(),
        })
    }
}

/// Child `i` must be an engine declaration.
pub(crate) fn engine<'a>(op: &Op, i: usize, tys: &[&'a Ty]) -> Result<&'a Op, TypeError> {
    tys[i].engine().ok_or_else(|| TypeError::Child {
        op: op.to_string(),
        child: i,
        got: tys[i].clone(),
        expected: "engine".into(),
    })
}

/// Shape-mismatch error constructor for registry shape rules.
pub(crate) fn shape_err(op: &Op, msg: impl Into<String>) -> TypeError {
    TypeError::Shape { op: op.to_string(), msg: msg.into() }
}

/// Output tile side for a valid (pre-padded) convolution/pool window sweep.
pub fn out_dim(i: usize, k: usize, stride: usize) -> Option<usize> {
    if i < k {
        return None;
    }
    if (i - k) % stride != 0 {
        return None;
    }
    Some((i - k) / stride + 1)
}

/// Input tile side needed to produce `o` outputs with window `k`, `stride`.
pub fn in_dim(o: usize, k: usize, stride: usize) -> usize {
    (o - 1) * stride + k
}

/// Infer the type of `op` given its children's types. This is the single
/// entry point for EngineIR's static semantics; the per-op rules live in
/// the [`crate::ir::spec`] registry.
pub fn infer(op: &Op, tys: &[Ty]) -> Result<Ty, TypeError> {
    let refs: Vec<&Ty> = tys.iter().collect();
    infer_ref(op, &refs)
}

/// By-reference variant of [`infer`] — the e-graph hot path uses this to
/// avoid cloning child types (shapes allocate) on every node insertion.
pub fn infer_ref(op: &Op, tys: &[&Ty]) -> Result<Ty, TypeError> {
    let spec = op.spec();
    if tys.len() != spec.arity {
        return Err(TypeError::Arity {
            op: op.to_string(),
            expected: spec.arity,
            got: tys.len(),
        });
    }
    (spec.shape)(op, tys)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::Symbol;

    fn t(dims: &[usize]) -> Ty {
        Ty::Tensor(Shape::new(dims))
    }

    #[test]
    fn conv2d_shape() {
        let ty = infer(
            &Op::Conv2d { stride: 1, pad_h: 2, pad_w: 2 },
            &[t(&[3, 32, 32]), t(&[8, 3, 3, 3])],
        )
        .unwrap();
        assert_eq!(ty, t(&[8, 32, 32]));
    }

    #[test]
    fn conv2d_stride2() {
        let ty = infer(
            &Op::Conv2d { stride: 2, pad_h: 0, pad_w: 0 },
            &[t(&[3, 33, 33]), t(&[8, 3, 3, 3])],
        )
        .unwrap();
        assert_eq!(ty, t(&[8, 16, 16]));
    }

    #[test]
    fn conv2d_rejects_channel_mismatch() {
        assert!(infer(
            &Op::Conv2d { stride: 1, pad_h: 0, pad_w: 0 },
            &[t(&[4, 8, 8]), t(&[8, 3, 3, 3])]
        )
        .is_err());
    }

    #[test]
    fn conv2d_accepts_rectangular_kernels() {
        // 1x7 kernel: H unchanged by kh=1, W shrinks by kw=7.
        let ty = infer(
            &Op::Conv2d { stride: 1, pad_h: 0, pad_w: 0 },
            &[t(&[3, 16, 16]), t(&[8, 3, 1, 7])],
        )
        .unwrap();
        assert_eq!(ty, t(&[8, 16, 10]));
    }

    #[test]
    fn dense_shape() {
        assert_eq!(infer(&Op::Dense, &[t(&[1, 784]), t(&[784, 128])]).unwrap(), t(&[1, 128]));
        assert!(infer(&Op::Dense, &[t(&[1, 784]), t(&[783, 128])]).is_err());
    }

    #[test]
    fn matmul_matches_dense_rule() {
        assert_eq!(infer(&Op::Matmul, &[t(&[16, 64]), t(&[64, 16])]).unwrap(), t(&[16, 16]));
    }

    #[test]
    fn batch_matmul_shape() {
        assert_eq!(
            infer(&Op::BatchMatmul, &[t(&[4, 2, 8]), t(&[4, 8, 2])]).unwrap(),
            t(&[4, 2, 2])
        );
        assert!(infer(&Op::BatchMatmul, &[t(&[4, 2, 8]), t(&[3, 8, 2])]).is_err());
    }

    #[test]
    fn transpose_softmax_layernorm_shapes() {
        assert_eq!(infer(&Op::Transpose, &[t(&[2, 5])]).unwrap(), t(&[5, 2]));
        // Batched (rank-3) transpose swaps the trailing axes.
        assert_eq!(infer(&Op::Transpose, &[t(&[4, 2, 5])]).unwrap(), t(&[4, 5, 2]));
        assert!(infer(&Op::Transpose, &[t(&[2, 3, 4, 5])]).is_err());
        assert_eq!(infer(&Op::Softmax, &[t(&[4, 8])]).unwrap(), t(&[4, 8]));
        // Rank-3 softmax (per-head attention scores) is row-wise too.
        assert_eq!(infer(&Op::Softmax, &[t(&[2, 3, 4])]).unwrap(), t(&[2, 3, 4]));
        assert!(infer(&Op::Softmax, &[t(&[2, 3, 4, 5])]).is_err());
        // Affine layernorm: gamma/beta must match the last axis.
        assert_eq!(
            infer(&Op::LayerNorm, &[t(&[8]), t(&[8]), t(&[8])]).unwrap(),
            t(&[8])
        );
        assert_eq!(
            infer(&Op::LayerNorm, &[t(&[2, 8]), t(&[8]), t(&[8])]).unwrap(),
            t(&[2, 8])
        );
        assert!(infer(&Op::LayerNorm, &[t(&[2, 8]), t(&[4]), t(&[8])]).is_err());
        assert!(infer(&Op::LayerNorm, &[t(&[2, 8]), t(&[8]), t(&[2])]).is_err());
    }

    #[test]
    fn emul_requires_same_shape() {
        assert_eq!(infer(&Op::Emul, &[t(&[4]), t(&[4])]).unwrap(), t(&[4]));
        assert!(infer(&Op::Emul, &[t(&[4]), t(&[5])]).is_err());
    }

    #[test]
    fn rect_pool_shape() {
        let ty = infer(
            &Op::MaxPool2d { kh: 2, kw: 4, stride: 2 },
            &[t(&[3, 8, 8])],
        )
        .unwrap();
        assert_eq!(ty, t(&[3, 4, 3]));
    }

    #[test]
    fn depthwise_conv_shape() {
        let ty = infer(
            &Op::DepthwiseConv2d { stride: 1, pad_h: 2, pad_w: 2 },
            &[t(&[16, 14, 14]), t(&[16, 3, 3])],
        )
        .unwrap();
        assert_eq!(ty, t(&[16, 14, 14]));
        // channel mismatch rejected
        assert!(infer(
            &Op::DepthwiseConv2d { stride: 1, pad_h: 0, pad_w: 0 },
            &[t(&[16, 14, 14]), t(&[8, 3, 3])]
        )
        .is_err());
    }

    #[test]
    fn invoke_mm_checks_engine_params() {
        let e = Ty::Engine(EngineSig(Op::MmEngine { m: 4, k: 8, n: 2 }));
        assert_eq!(
            infer(&Op::InvokeMm, &[e.clone(), t(&[4, 8]), t(&[8, 2])]).unwrap(),
            t(&[4, 2])
        );
        assert!(infer(&Op::InvokeMm, &[e, t(&[4, 8]), t(&[8, 3])]).is_err());
    }

    #[test]
    fn invoke_conv_halo_shape() {
        // 2x4 output tile, 3x3 kernel, stride 1 -> needs (2-1)+3 = 4 rows in.
        let e = Ty::Engine(EngineSig(Op::ConvEngine {
            oh: 2,
            ow: 4,
            c: 3,
            k: 8,
            kh: 3,
            kw: 3,
            stride: 1,
        }));
        let ty = infer(&Op::InvokeConv, &[e, t(&[3, 4, 6]), t(&[8, 3, 3, 3])]).unwrap();
        assert_eq!(ty, t(&[8, 2, 4]));
    }

    #[test]
    fn invoke_dwconv_halo_shape() {
        let e = Ty::Engine(EngineSig(Op::DwConvEngine {
            oh: 2,
            ow: 4,
            c: 3,
            kh: 3,
            kw: 3,
            stride: 1,
        }));
        let ty = infer(&Op::InvokeDwConv, &[e, t(&[3, 4, 6]), t(&[3, 3, 3])]).unwrap();
        assert_eq!(ty, t(&[3, 2, 4]));
    }

    #[test]
    fn sched_loop_multiplies_axis() {
        let v = Symbol::new("i");
        let ty =
            infer(&Op::SchedLoop { var: v, axis: 1, extent: 4 }, &[t(&[8, 2, 4])]).unwrap();
        assert_eq!(ty, t(&[8, 8, 4]));
    }

    #[test]
    fn slice_keeps_static_shape_with_dynamic_start() {
        let ty = infer(&Op::SliceAx { axis: 1, len: 16 }, &[Ty::Index, t(&[3, 32, 32])]).unwrap();
        assert_eq!(ty, t(&[3, 16, 32]));
    }

    #[test]
    fn reshape_checks_numel() {
        assert!(infer(&Op::Reshape(Shape::new(&[2, 8])), &[t(&[4, 4])]).is_ok());
        assert!(infer(&Op::Reshape(Shape::new(&[2, 9])), &[t(&[4, 4])]).is_err());
    }

    #[test]
    fn im2col_shape() {
        // (3,32,32) with 3x3 stride 1 -> (27, 900)
        let ty = infer(&Op::Im2Col { kh: 3, kw: 3, stride: 1 }, &[t(&[3, 32, 32])]).unwrap();
        assert_eq!(ty, t(&[27, 900]));
        // Rectangular 3x1 window: (3*3*1, 30*32)
        let ty = infer(&Op::Im2Col { kh: 3, kw: 1, stride: 1 }, &[t(&[3, 32, 32])]).unwrap();
        assert_eq!(ty, t(&[9, 960]));
    }

    #[test]
    fn out_in_dims_roundtrip() {
        for stride in 1..4 {
            for k in 1..5 {
                for o in 1..10 {
                    let i = in_dim(o, k, stride);
                    assert_eq!(out_dim(i, k, stride), Some(o));
                }
            }
        }
    }
}
