//! S-expression parser for EngineIR — the inverse of [`super::print`].
//!
//! Grammar (informal):
//!
//! ```text
//! expr   := INT | (HEAD attr* expr*)
//! attr   := INT | SYM | shape | floats | 'sram' | 'dram'
//! shape  := '[' INT* ']'
//! floats := '[' FLOAT* ']'
//! ```
//!
//! The parser is fully registry-driven: the head symbol selects an
//! [`crate::ir::spec::OpSpec`], whose attribute schema drives attr reading
//! and whose arity drives child reading. Adding an op requires no change
//! here.

use super::op::{BufKind, Op};
use super::recexpr::{Node, RecExpr};
use super::shape::Shape;
use super::spec::{self, AttrKind, AttrVal};
use super::symbol::Symbol;
use crate::egraph::Id;

/// A parse failure, with a human-readable message.
#[derive(Debug, Clone)]
pub struct ParseError(pub String);

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "parse error: {}", self.0)
    }
}

impl std::error::Error for ParseError {}

type Result<T> = std::result::Result<T, ParseError>;

#[derive(Debug, Clone, PartialEq)]
enum Tok {
    LParen,
    RParen,
    LBrack,
    RBrack,
    Atom(String),
}

fn lex(src: &str) -> Vec<Tok> {
    let mut toks = Vec::new();
    let mut cur = String::new();
    let flush = |cur: &mut String, toks: &mut Vec<Tok>| {
        if !cur.is_empty() {
            toks.push(Tok::Atom(std::mem::take(cur)));
        }
    };
    for ch in src.chars() {
        match ch {
            '(' => {
                flush(&mut cur, &mut toks);
                toks.push(Tok::LParen);
            }
            ')' => {
                flush(&mut cur, &mut toks);
                toks.push(Tok::RParen);
            }
            '[' => {
                flush(&mut cur, &mut toks);
                toks.push(Tok::LBrack);
            }
            ']' => {
                flush(&mut cur, &mut toks);
                toks.push(Tok::RBrack);
            }
            c if c.is_whitespace() => flush(&mut cur, &mut toks),
            c => cur.push(c),
        }
    }
    flush(&mut cur, &mut toks);
    toks
}

struct Parser<'a> {
    toks: &'a [Tok],
    pos: usize,
    expr: RecExpr,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<&Tok> {
        self.toks.get(self.pos)
    }

    fn next(&mut self) -> Result<&Tok> {
        let t = self.toks.get(self.pos).ok_or_else(|| ParseError("unexpected EOF".into()))?;
        self.pos += 1;
        Ok(t)
    }

    fn expect(&mut self, tok: Tok) -> Result<()> {
        let t = self.next()?;
        if *t == tok {
            Ok(())
        } else {
            Err(ParseError(format!("expected {tok:?}, got {t:?}")))
        }
    }

    fn atom(&mut self) -> Result<String> {
        match self.next()? {
            Tok::Atom(s) => Ok(s.clone()),
            t => Err(ParseError(format!("expected atom, got {t:?}"))),
        }
    }

    fn usize_atom(&mut self) -> Result<usize> {
        let a = self.atom()?;
        a.parse().map_err(|_| ParseError(format!("expected integer, got '{a}'")))
    }

    fn i64_atom(&mut self) -> Result<i64> {
        let a = self.atom()?;
        a.parse().map_err(|_| ParseError(format!("expected integer, got '{a}'")))
    }

    fn sym_atom(&mut self) -> Result<Symbol> {
        Ok(Symbol::new(&self.atom()?))
    }

    fn shape(&mut self) -> Result<Shape> {
        self.expect(Tok::LBrack)?;
        let mut dims = Vec::new();
        loop {
            match self.peek() {
                Some(Tok::RBrack) => {
                    self.pos += 1;
                    return Ok(Shape(dims));
                }
                Some(Tok::Atom(_)) => dims.push(self.usize_atom()?),
                t => return Err(ParseError(format!("bad shape token {t:?}"))),
            }
        }
    }

    fn f32_list(&mut self) -> Result<Vec<f32>> {
        self.expect(Tok::LBrack)?;
        let mut vals = Vec::new();
        loop {
            match self.peek() {
                Some(Tok::RBrack) => {
                    self.pos += 1;
                    return Ok(vals);
                }
                Some(Tok::Atom(_)) => {
                    let a = self.atom()?;
                    vals.push(
                        a.parse()
                            .map_err(|_| ParseError(format!("expected float, got '{a}'")))?,
                    );
                }
                t => return Err(ParseError(format!("bad float-list token {t:?}"))),
            }
        }
    }

    fn bufkind(&mut self) -> Result<BufKind> {
        match self.atom()?.as_str() {
            "sram" => Ok(BufKind::Sram),
            "dram" => Ok(BufKind::Dram),
            s => Err(ParseError(format!("unknown buffer kind '{s}'"))),
        }
    }

    fn expr(&mut self) -> Result<Id> {
        match self.next()?.clone() {
            Tok::Atom(a) => {
                let v: i64 =
                    a.parse().map_err(|_| ParseError(format!("bare atom '{a}' is not int")))?;
                Ok(self.expr.add_leaf(Op::Int(v)))
            }
            Tok::LParen => {
                let head = self.atom()?;
                let id = self.form(&head)?;
                self.expect(Tok::RParen)?;
                Ok(id)
            }
            t => Err(ParseError(format!("unexpected token {t:?}"))),
        }
    }

    fn children(&mut self, n: usize) -> Result<Vec<Id>> {
        (0..n).map(|_| self.expr()).collect()
    }

    /// Schema-driven form parsing: head → spec; read each attribute slot
    /// per the spec's schema, rebuild the op, then read `arity` children.
    fn form(&mut self, head: &str) -> Result<Id> {
        let spec = spec::by_name(head)
            .ok_or_else(|| ParseError(format!("unknown form '{head}'")))?;
        let mut attrs = Vec::with_capacity(spec.attrs.len());
        for (_, kind) in spec.attrs {
            attrs.push(match kind {
                AttrKind::U => AttrVal::U(self.usize_atom()?),
                AttrKind::I => AttrVal::I(self.i64_atom()?),
                AttrKind::Sym => AttrVal::Sym(self.sym_atom()?),
                AttrKind::Sh => AttrVal::Sh(self.shape()?),
                AttrKind::Buf => AttrVal::Buf(self.bufkind()?),
                AttrKind::F32s => AttrVal::F32s(self.f32_list()?),
            });
        }
        let op = (spec.from_attrs)(&attrs)
            .ok_or_else(|| ParseError(format!("bad attributes for '{head}'")))?;
        let kids = self.children(spec.arity)?;
        Ok(self.expr.add(Node::new(op, kids)))
    }
}

/// Parse a single EngineIR expression.
pub fn parse_expr(src: &str) -> Result<RecExpr> {
    let toks = lex(src);
    let mut p = Parser { toks: &toks, pos: 0, expr: RecExpr::new() };
    p.expr()?;
    if p.pos != p.toks.len() {
        return Err(ParseError(format!("trailing tokens at {}", p.pos)));
    }
    Ok(p.expr)
}

/// `"(relu …)".parse::<RecExpr>()` — the idiomatic entry point; errors are
/// the crate-wide typed [`crate::error::Error`].
impl std::str::FromStr for RecExpr {
    type Err = crate::error::Error;

    fn from_str(src: &str) -> std::result::Result<Self, Self::Err> {
        parse_expr(src).map_err(Into::into)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const CASES: &[&str] = &[
        "(invoke-relu (relu-engine 128) (input x [128]))",
        "(sched-loop i0 0 2 (invoke-relu (relu-engine 64) (slice 0 64 (imul (lvar i0) 64) (input x [128]))))",
        "(sched-par p1 0 2 (invoke-relu (relu-engine 64) (slice 0 64 (imul (lvar p1) 64) (input x [128]))))",
        "(invoke-mm (mm-engine 16 16 16) (input a [16 16]) (weight w [16 16]))",
        "(dense (flatten (maxpool2d 2 2 2 (relu (conv2d 1 2 2 (input img [3 32 32]) (weight k1 [8 3 3 3]))))) (weight w2 [2048 10]))",
        "(maxpool2d 2 4 2 (input img [3 8 8]))",
        "(invoke-pool (pool-engine 2 2 3 2 4 2) (input x [3 4 6]))",
        "(invoke-conv (conv-engine 2 4 3 8 3 3 1) (slice 1 4 (imul (lvar i) 2) (pad2d 2 2 (input img [3 8 8]))) (weight k [8 3 3 3]))",
        "(sched-reduce r0 2 (invoke-mm (mm-engine 4 8 4) (slice 1 8 (imul (lvar r0) 8) (input a [4 16])) (slice 0 8 (imul (lvar r0) 8) (weight b [16 4]))))",
        "(buffer sram (reshape [1 16] (invoke-relu (relu-engine 16) (reshape [16] (input x [4 4])))))",
        "(eadd (bcast [8] (weight b [8])) (gap (input t [8 5 5])))",
        "(matmul (softmax (matmul (input q [4 8]) (transpose (input k [4 8])))) (input v [4 8]))",
        "(layernorm (gelu (dense (input x [2 16]) (weight w [16 16]))) (weight g [16]) (weight b [16]))",
        "(emul (input x [8]) (input y [8]))",
        "(invoke-emul (emul-engine 8) (input x [8]) (input y [8]))",
        "(transpose (input p [2 4 8]))",
        "(dwconv2d 1 2 2 (input img [8 14 14]) (weight dw [8 3 3]))",
        "(invoke-dw-conv (dw-conv-engine 4 4 8 3 3 1) (input x [8 6 6]) (weight w [8 3 3]))",
        "(batch-matmul (input a [2 4 8]) (input b [2 8 4]))",
        "(emul (input x [2 2]) (const [2 2] [1.5 -0.25 0.0 3.5]))",
    ];

    #[test]
    fn roundtrip_print_parse() {
        for src in CASES {
            let e = parse_expr(src).unwrap_or_else(|err| panic!("{src}: {err}"));
            assert_eq!(&e.to_string(), src);
        }
    }

    #[test]
    fn parses_shapes() {
        let e = parse_expr("(input x [3 32 32])").unwrap();
        assert_eq!(e.len(), 1);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse_expr("(frobnicate 1 2)").is_err());
        assert!(parse_expr("(relu").is_err());
        assert!(parse_expr("(relu (input x [4])) trailing").is_err());
        assert!(parse_expr("").is_err());
        // wrong attribute kind for the schema
        assert!(parse_expr("(buffer nowhere (input x [4]))").is_err());
    }

    #[test]
    fn typechecks_parsed_workload() {
        // a small conv -> relu -> pool -> flatten -> dense chain
        let e = parse_expr(CASES[4]).unwrap();
        let ty = e.typecheck().unwrap();
        assert_eq!(ty, crate::ir::Ty::Tensor(crate::ir::Shape::new(&[1, 10])));
    }

    #[test]
    fn typechecks_attention_core() {
        // softmax(q @ k^T) @ v — the single-head attention core.
        let e = parse_expr(CASES[11]).unwrap();
        let ty = e.typecheck().unwrap();
        assert_eq!(ty, crate::ir::Ty::Tensor(crate::ir::Shape::new(&[4, 8])));
    }
}
