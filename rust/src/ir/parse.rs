//! S-expression parser for EngineIR — the inverse of [`super::print`].
//!
//! Grammar (informal):
//!
//! ```text
//! expr   := INT | (lvar SYM) | (imul e e) | (iadd e e)
//!         | (input SYM shape) | (weight SYM shape)
//!         | (conv2d STRIDE PAD e e) | (dense e e) | (relu e) | ...
//!         | (mm-engine M K N) | (relu-engine W) | ...
//!         | (invoke-mm e e e) | ...
//!         | (sched-loop SYM AXIS EXTENT e) | (sched-par ...) | (sched-reduce SYM EXTENT e)
//!         | (slice AXIS LEN e e) | (reshape shape e) | (buffer KIND e) | ...
//! shape  := '[' INT* ']'
//! ```

use super::op::{BufKind, Op};
use super::recexpr::{Node, RecExpr};
use super::shape::Shape;
use super::symbol::Symbol;
use crate::egraph::Id;

/// A parse failure, with a human-readable message.
#[derive(Debug, Clone)]
pub struct ParseError(pub String);

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "parse error: {}", self.0)
    }
}

impl std::error::Error for ParseError {}

type Result<T> = std::result::Result<T, ParseError>;

#[derive(Debug, Clone, PartialEq)]
enum Tok {
    LParen,
    RParen,
    LBrack,
    RBrack,
    Atom(String),
}

fn lex(src: &str) -> Vec<Tok> {
    let mut toks = Vec::new();
    let mut cur = String::new();
    let flush = |cur: &mut String, toks: &mut Vec<Tok>| {
        if !cur.is_empty() {
            toks.push(Tok::Atom(std::mem::take(cur)));
        }
    };
    for ch in src.chars() {
        match ch {
            '(' => {
                flush(&mut cur, &mut toks);
                toks.push(Tok::LParen);
            }
            ')' => {
                flush(&mut cur, &mut toks);
                toks.push(Tok::RParen);
            }
            '[' => {
                flush(&mut cur, &mut toks);
                toks.push(Tok::LBrack);
            }
            ']' => {
                flush(&mut cur, &mut toks);
                toks.push(Tok::RBrack);
            }
            c if c.is_whitespace() => flush(&mut cur, &mut toks),
            c => cur.push(c),
        }
    }
    flush(&mut cur, &mut toks);
    toks
}

struct Parser<'a> {
    toks: &'a [Tok],
    pos: usize,
    expr: RecExpr,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<&Tok> {
        self.toks.get(self.pos)
    }

    fn next(&mut self) -> Result<&Tok> {
        let t = self.toks.get(self.pos).ok_or_else(|| ParseError("unexpected EOF".into()))?;
        self.pos += 1;
        Ok(t)
    }

    fn expect(&mut self, tok: Tok) -> Result<()> {
        let t = self.next()?;
        if *t == tok {
            Ok(())
        } else {
            Err(ParseError(format!("expected {tok:?}, got {t:?}")))
        }
    }

    fn atom(&mut self) -> Result<String> {
        match self.next()? {
            Tok::Atom(s) => Ok(s.clone()),
            t => Err(ParseError(format!("expected atom, got {t:?}"))),
        }
    }

    fn usize_atom(&mut self) -> Result<usize> {
        let a = self.atom()?;
        a.parse().map_err(|_| ParseError(format!("expected integer, got '{a}'")))
    }

    fn sym_atom(&mut self) -> Result<Symbol> {
        Ok(Symbol::new(&self.atom()?))
    }

    fn shape(&mut self) -> Result<Shape> {
        self.expect(Tok::LBrack)?;
        let mut dims = Vec::new();
        loop {
            match self.peek() {
                Some(Tok::RBrack) => {
                    self.pos += 1;
                    return Ok(Shape(dims));
                }
                Some(Tok::Atom(_)) => dims.push(self.usize_atom()?),
                t => return Err(ParseError(format!("bad shape token {t:?}"))),
            }
        }
    }

    fn bufkind(&mut self) -> Result<BufKind> {
        match self.atom()?.as_str() {
            "sram" => Ok(BufKind::Sram),
            "dram" => Ok(BufKind::Dram),
            s => Err(ParseError(format!("unknown buffer kind '{s}'"))),
        }
    }

    fn expr(&mut self) -> Result<Id> {
        match self.next()?.clone() {
            Tok::Atom(a) => {
                let v: i64 =
                    a.parse().map_err(|_| ParseError(format!("bare atom '{a}' is not int")))?;
                Ok(self.expr.add_leaf(Op::Int(v)))
            }
            Tok::LParen => {
                let head = self.atom()?;
                let id = self.form(&head)?;
                self.expect(Tok::RParen)?;
                Ok(id)
            }
            t => Err(ParseError(format!("unexpected token {t:?}"))),
        }
    }

    fn children(&mut self, n: usize) -> Result<Vec<Id>> {
        (0..n).map(|_| self.expr()).collect()
    }

    fn form(&mut self, head: &str) -> Result<Id> {
        let e = match head {
            "lvar" => Node::leaf(Op::LVar(self.sym_atom()?)),
            "imul" => Node::new(Op::IMul, self.children(2)?),
            "iadd" => Node::new(Op::IAdd, self.children(2)?),
            "input" => {
                let s = self.sym_atom()?;
                Node::leaf(Op::Input(s, self.shape()?))
            }
            "weight" => {
                let s = self.sym_atom()?;
                Node::leaf(Op::Weight(s, self.shape()?))
            }
            "conv2d" => {
                let stride = self.usize_atom()?;
                let pad = self.usize_atom()?;
                Node::new(Op::Conv2d { stride, pad }, self.children(2)?)
            }
            "dense" => Node::new(Op::Dense, self.children(2)?),
            "relu" => Node::new(Op::Relu, self.children(1)?),
            "bias-add" => Node::new(Op::BiasAdd, self.children(2)?),
            "eadd" => Node::new(Op::EAdd, self.children(2)?),
            "maxpool2d" => {
                let k = self.usize_atom()?;
                let stride = self.usize_atom()?;
                Node::new(Op::MaxPool2d { k, stride }, self.children(1)?)
            }
            "flatten" => Node::new(Op::Flatten, self.children(1)?),
            "gap" => Node::new(Op::GlobalAvgPool, self.children(1)?),
            "mm-engine" => {
                let (m, k, n) = (self.usize_atom()?, self.usize_atom()?, self.usize_atom()?);
                Node::leaf(Op::MmEngine { m, k, n })
            }
            "mm-relu-engine" => {
                let (m, k, n) = (self.usize_atom()?, self.usize_atom()?, self.usize_atom()?);
                Node::leaf(Op::MmReluEngine { m, k, n })
            }
            "relu-engine" => Node::leaf(Op::ReluEngine { w: self.usize_atom()? }),
            "add-engine" => Node::leaf(Op::AddEngine { w: self.usize_atom()? }),
            "conv-engine" => {
                let oh = self.usize_atom()?;
                let ow = self.usize_atom()?;
                let c = self.usize_atom()?;
                let k = self.usize_atom()?;
                let kh = self.usize_atom()?;
                let stride = self.usize_atom()?;
                Node::leaf(Op::ConvEngine { oh, ow, c, k, kh, stride })
            }
            "pool-engine" => {
                let oh = self.usize_atom()?;
                let ow = self.usize_atom()?;
                let c = self.usize_atom()?;
                let k = self.usize_atom()?;
                let stride = self.usize_atom()?;
                Node::leaf(Op::PoolEngine { oh, ow, c, k, stride })
            }
            "invoke-mm" => Node::new(Op::InvokeMm, self.children(3)?),
            "invoke-mm-relu" => Node::new(Op::InvokeMmRelu, self.children(3)?),
            "invoke-relu" => Node::new(Op::InvokeRelu, self.children(2)?),
            "invoke-add" => Node::new(Op::InvokeAdd, self.children(3)?),
            "invoke-conv" => Node::new(Op::InvokeConv, self.children(3)?),
            "invoke-pool" => Node::new(Op::InvokePool, self.children(2)?),
            "sched-loop" | "sched-par" => {
                let var = self.sym_atom()?;
                let axis = self.usize_atom()?;
                let extent = self.usize_atom()?;
                let kids = self.children(1)?;
                let op = if head == "sched-loop" {
                    Op::SchedLoop { var, axis, extent }
                } else {
                    Op::SchedPar { var, axis, extent }
                };
                Node::new(op, kids)
            }
            "sched-reduce" => {
                let var = self.sym_atom()?;
                let extent = self.usize_atom()?;
                Node::new(Op::SchedReduce { var, extent }, self.children(1)?)
            }
            "slice" => {
                let axis = self.usize_atom()?;
                let len = self.usize_atom()?;
                Node::new(Op::SliceAx { axis, len }, self.children(2)?)
            }
            "reshape" => {
                let sh = self.shape()?;
                Node::new(Op::Reshape(sh), self.children(1)?)
            }
            "bcast" => {
                let sh = self.shape()?;
                Node::new(Op::Bcast(sh), self.children(1)?)
            }
            "pad2d" => Node::new(Op::Pad2d { pad: self.usize_atom()? }, self.children(1)?),
            "im2col" => {
                let kh = self.usize_atom()?;
                let stride = self.usize_atom()?;
                Node::new(Op::Im2Col { kh, stride }, self.children(1)?)
            }
            "buffer" => Node::new(Op::Buffer { kind: self.bufkind()? }, self.children(1)?),
            "dbl-buffer" => Node::new(Op::DblBuffer { kind: self.bufkind()? }, self.children(1)?),
            other => return Err(ParseError(format!("unknown form '{other}'"))),
        };
        Ok(self.expr.add(e))
    }
}

/// Parse a single EngineIR expression.
pub fn parse_expr(src: &str) -> Result<RecExpr> {
    let toks = lex(src);
    let mut p = Parser { toks: &toks, pos: 0, expr: RecExpr::new() };
    p.expr()?;
    if p.pos != p.toks.len() {
        return Err(ParseError(format!("trailing tokens at {}", p.pos)));
    }
    Ok(p.expr)
}

/// `"(relu …)".parse::<RecExpr>()` — the idiomatic entry point; errors are
/// the crate-wide typed [`crate::error::Error`].
impl std::str::FromStr for RecExpr {
    type Err = crate::error::Error;

    fn from_str(src: &str) -> std::result::Result<Self, Self::Err> {
        parse_expr(src).map_err(Into::into)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const CASES: &[&str] = &[
        "(invoke-relu (relu-engine 128) (input x [128]))",
        "(sched-loop i0 0 2 (invoke-relu (relu-engine 64) (slice 0 64 (imul (lvar i0) 64) (input x [128]))))",
        "(sched-par p1 0 2 (invoke-relu (relu-engine 64) (slice 0 64 (imul (lvar p1) 64) (input x [128]))))",
        "(invoke-mm (mm-engine 16 16 16) (input a [16 16]) (weight w [16 16]))",
        "(dense (flatten (maxpool2d 2 2 (relu (conv2d 1 1 (input img [3 32 32]) (weight k1 [8 3 3 3]))))) (weight w2 [2048 10]))",
        "(invoke-conv (conv-engine 2 4 3 8 3 1) (slice 1 4 (imul (lvar i) 2) (pad2d 1 (input img [3 8 8]))) (weight k [8 3 3 3]))",
        "(sched-reduce r0 2 (invoke-mm (mm-engine 4 8 4) (slice 1 8 (imul (lvar r0) 8) (input a [4 16])) (slice 0 8 (imul (lvar r0) 8) (weight b [16 4]))))",
        "(buffer sram (reshape [1 16] (invoke-relu (relu-engine 16) (reshape [16] (input x [4 4])))))",
        "(eadd (bcast [8] (weight b [8])) (gap (input t [8 5 5])))",
    ];

    #[test]
    fn roundtrip_print_parse() {
        for src in CASES {
            let e = parse_expr(src).unwrap_or_else(|err| panic!("{src}: {err}"));
            assert_eq!(&e.to_string(), src);
        }
    }

    #[test]
    fn parses_shapes() {
        let e = parse_expr("(input x [3 32 32])").unwrap();
        assert_eq!(e.len(), 1);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse_expr("(frobnicate 1 2)").is_err());
        assert!(parse_expr("(relu").is_err());
        assert!(parse_expr("(relu (input x [4])) trailing").is_err());
        assert!(parse_expr("").is_err());
    }

    #[test]
    fn typechecks_parsed_workload() {
        // a small conv -> relu -> pool -> flatten -> dense chain
        let e = parse_expr(CASES[4]).unwrap();
        let ty = e.typecheck().unwrap();
        assert_eq!(ty, crate::ir::Ty::Tensor(crate::ir::Shape::new(&[1, 10])));
    }
}
