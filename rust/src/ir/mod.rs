//! EngineIR: the term language over which designs are enumerated.
//!
//! EngineIR reifies the three components the paper identifies in an
//! accelerated ML inference workload (§2):
//!
//! * **hardware engines** — fixed-size compute units, declared with concrete
//!   parameters (e.g. `(mm-engine 16 16 16)` is a 16×16×16 matrix-multiply
//!   unit, `(relu-engine 128)` a 128-wide ReLU unit);
//! * **software schedules** — loops (`sched-loop`) and parallel maps
//!   (`sched-par`) that expand fixed-size engine invocations to
//!   arbitrary-size tensors, plus reductions (`sched-reduce`);
//! * **storage** — explicit `buffer` / `dbl-buffer` materialization points
//!   carrying intermediates between invocations.
//!
//! Relay-level operators (`conv2d`, `dense`, `relu`, …) are also terms of the
//! language, so a *partially reified* program (some ops still at the Relay
//! level, some already split into engines + schedules) is representable —
//! that is what lets rewrites explore the hardware–software split
//! incrementally inside one e-graph.

pub mod op;
pub mod parse;
pub mod print;
pub mod recexpr;
pub mod shape;
pub mod spec;
pub mod symbol;

pub use op::{BufKind, ConstData, Op, OpKind};
pub use spec::{OpClass, OpSpec};
pub use parse::parse_expr;
pub use recexpr::{Node, RecExpr};
pub use shape::{infer as infer_ty, infer_ref as infer_ty_ref, in_dim, out_dim, EngineSig, Shape, Ty, TypeError};
pub use symbol::Symbol;
