//! `RecExpr`: a flattened expression DAG (postorder array of nodes whose
//! children are indices into the same array). This is both the concrete
//! program representation (what the parser yields, what extraction returns,
//! what the evaluator/simulator consume) and the unit of insertion into the
//! e-graph.

use super::op::Op;
use super::shape::{infer, Ty, TypeError};
use super::symbol::Symbol;
use crate::egraph::Id;
use std::fmt;

/// One operator application; children point at e-classes (in an
/// [`crate::egraph::EGraph`]) or at earlier `RecExpr` slots.
#[derive(Clone, PartialEq, Eq, Hash, Debug)]
pub struct Node {
    pub op: Op,
    pub children: Vec<Id>,
}

impl Node {
    pub fn new(op: Op, children: Vec<Id>) -> Self {
        debug_assert!(
            op.arity().map_or(true, |a| a == children.len()),
            "arity mismatch for {op}: got {}",
            children.len()
        );
        Node { op, children }
    }

    pub fn leaf(op: Op) -> Self {
        Node::new(op, vec![])
    }

    /// Copy with children rewritten through `f` (used by canonicalization
    /// and by e-graph insertion).
    pub fn map_children(&self, mut f: impl FnMut(Id) -> Id) -> Node {
        Node { op: self.op.clone(), children: self.children.iter().map(|&c| f(c)).collect() }
    }
}

/// A self-contained expression: `nodes[i]`'s children all have index < `i`;
/// the root is the last node.
#[derive(Clone, PartialEq, Eq, Debug, Default)]
pub struct RecExpr {
    nodes: Vec<Node>,
}

impl RecExpr {
    pub fn new() -> Self {
        RecExpr { nodes: Vec::new() }
    }

    /// Append a node; children must reference earlier slots.
    pub fn add(&mut self, node: Node) -> Id {
        for &c in &node.children {
            assert!((c.index()) < self.nodes.len(), "RecExpr child out of range");
        }
        self.nodes.push(node);
        Id::from_index(self.nodes.len() - 1)
    }

    /// Convenience: append `op` applied to `children`.
    pub fn add_op(&mut self, op: Op, children: &[Id]) -> Id {
        self.add(Node::new(op, children.to_vec()))
    }

    pub fn add_leaf(&mut self, op: Op) -> Id {
        self.add(Node::leaf(op))
    }

    pub fn nodes(&self) -> &[Node] {
        &self.nodes
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    pub fn node(&self, id: Id) -> &Node {
        &self.nodes[id.index()]
    }

    /// Root node id (the last slot).
    pub fn root(&self) -> Id {
        assert!(!self.nodes.is_empty(), "empty RecExpr has no root");
        Id::from_index(self.nodes.len() - 1)
    }

    /// Type-check the whole expression; returns the root type.
    /// Duplicate work is shared: each slot is inferred once.
    pub fn typecheck(&self) -> Result<Ty, TypeError> {
        let mut tys: Vec<Ty> = Vec::with_capacity(self.nodes.len());
        for node in &self.nodes {
            let child_tys: Vec<Ty> =
                node.children.iter().map(|c| tys[c.index()].clone()).collect();
            tys.push(infer(&node.op, &child_tys)?);
        }
        Ok(tys.last().cloned().expect("empty expr"))
    }

    /// Per-slot types (same traversal as [`Self::typecheck`]).
    pub fn types(&self) -> Result<Vec<Ty>, TypeError> {
        let mut tys: Vec<Ty> = Vec::with_capacity(self.nodes.len());
        for node in &self.nodes {
            let child_tys: Vec<Ty> =
                node.children.iter().map(|c| tys[c.index()].clone()).collect();
            tys.push(infer(&node.op, &child_tys)?);
        }
        Ok(tys)
    }

    /// Copy the subtree rooted at `root` in `other` into `self`, returning
    /// the new root id. Structurally identical nodes — including ones
    /// already present in `self` from earlier appends — are deduplicated,
    /// so repeated appends of the same subtree are idempotent.
    pub fn append_subtree(&mut self, other: &RecExpr, root: Id) -> Id {
        let mut existing: std::collections::HashMap<Node, Id> = self
            .nodes
            .iter()
            .enumerate()
            .map(|(i, n)| (n.clone(), Id::from_index(i)))
            .collect();
        let mut map: Vec<Option<Id>> = vec![None; other.len()];
        self.append_rec(other, root, &mut map, &mut existing)
    }

    fn append_rec(
        &mut self,
        other: &RecExpr,
        id: Id,
        map: &mut Vec<Option<Id>>,
        existing: &mut std::collections::HashMap<Node, Id>,
    ) -> Id {
        if let Some(done) = map[id.index()] {
            return done;
        }
        let node = other.node(id);
        let children: Vec<Id> = node
            .children
            .iter()
            .map(|&c| self.append_rec(other, c, map, existing))
            .collect();
        let candidate = Node::new(node.op.clone(), children);
        let new_id = if let Some(&found) = existing.get(&candidate) {
            found
        } else {
            let id = self.add(candidate.clone());
            existing.insert(candidate, id);
            id
        };
        map[id.index()] = Some(new_id);
        new_id
    }

    /// Count of nodes with `pred` true.
    pub fn count(&self, pred: impl Fn(&Op) -> bool) -> usize {
        self.nodes.iter().filter(|n| pred(&n.op)).count()
    }

    /// Per-slot free schedule variables: `free()[i]` is the set of loop
    /// variables slot `i` depends on. A slot with an empty set is
    /// *loop-invariant*: it computes the same value on every iteration of
    /// every enclosing schedule, so the evaluator memoizes it and the cost
    /// model/simulator treat it as materialized once (hoisted) rather than
    /// recomputed per iteration.
    pub fn free_lvars(&self) -> Vec<Vec<Symbol>> {
        let mut free: Vec<Vec<Symbol>> = Vec::with_capacity(self.len());
        for node in &self.nodes {
            let mut f: Vec<Symbol> = match &node.op {
                Op::LVar(s) => vec![*s],
                _ => vec![],
            };
            for &c in &node.children {
                for s in &free[c.index()] {
                    if !f.contains(s) {
                        f.push(*s);
                    }
                }
            }
            // A schedule binds its variable: it is no longer free above.
            if let Op::SchedLoop { var, .. }
            | Op::SchedPar { var, .. }
            | Op::SchedReduce { var, .. } = &node.op
            {
                f.retain(|s| s != var);
            }
            f.sort();
            free.push(f);
        }
        free
    }

    /// The distinct engine declarations appearing in this design.
    pub fn engines(&self) -> Vec<Op> {
        let mut v: Vec<Op> = Vec::new();
        for n in &self.nodes {
            if n.op.is_engine() && !v.contains(&n.op) {
                v.push(n.op.clone());
            }
        }
        v
    }
}

impl fmt::Display for RecExpr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.nodes.is_empty() {
            return write!(f, "()");
        }
        write!(f, "{}", super::print::to_sexpr(self, self.root()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{Shape, Symbol};

    fn relu128() -> RecExpr {
        let mut e = RecExpr::new();
        let x = e.add_leaf(Op::Input(Symbol::new("x"), Shape::new(&[128])));
        let eng = e.add_leaf(Op::ReluEngine { w: 128 });
        e.add_op(Op::InvokeRelu, &[eng, x]);
        e
    }

    #[test]
    fn build_and_typecheck() {
        let e = relu128();
        assert_eq!(e.typecheck().unwrap(), Ty::Tensor(Shape::new(&[128])));
    }

    #[test]
    fn root_is_last() {
        let e = relu128();
        assert_eq!(e.node(e.root()).op, Op::InvokeRelu);
    }

    #[test]
    fn append_subtree_dedups() {
        let src = relu128();
        let mut dst = RecExpr::new();
        let a = dst.append_subtree(&src, src.root());
        let b = dst.append_subtree(&src, src.root());
        assert_eq!(dst.node(a), dst.node(b));
    }

    #[test]
    fn engines_deduplicated() {
        let mut e = RecExpr::new();
        let x = e.add_leaf(Op::Input(Symbol::new("x"), Shape::new(&[4])));
        let eng = e.add_leaf(Op::ReluEngine { w: 4 });
        let r1 = e.add_op(Op::InvokeRelu, &[eng, x]);
        let eng2 = e.add_leaf(Op::ReluEngine { w: 4 });
        let _r2 = e.add_op(Op::InvokeRelu, &[eng2, r1]);
        assert_eq!(e.engines().len(), 1);
    }

    #[test]
    #[should_panic]
    fn add_rejects_forward_refs() {
        let mut e = RecExpr::new();
        e.add(Node::new(Op::Relu, vec![Id::from_index(3)]));
    }
}
