//! Interned strings. `Symbol` is a 4-byte handle into a global intern table;
//! equality/hashing are O(1), which matters because symbols appear in every
//! hashconsed e-node (loop variables, tensor names, buffer kinds).

use std::collections::HashMap;
use std::fmt;
use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::{Mutex, OnceLock};

struct Interner {
    names: Vec<&'static str>,
    ids: HashMap<&'static str, u32>,
}

static INTERNER: OnceLock<Mutex<Interner>> = OnceLock::new();

fn interner() -> &'static Mutex<Interner> {
    INTERNER.get_or_init(|| Mutex::new(Interner { names: Vec::new(), ids: HashMap::new() }))
}

/// Monotonic counter backing [`Symbol::fresh`]. Fresh names are how rewrite
/// appliers introduce loop variables without capture: every generated
/// schedule binds a globally unique variable.
static FRESH: AtomicU32 = AtomicU32::new(0);

/// An interned string.
#[derive(Copy, Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Symbol(u32);

impl Symbol {
    /// Intern `s`, returning its handle. Idempotent.
    pub fn new(s: &str) -> Self {
        let mut t = interner().lock().unwrap();
        if let Some(&id) = t.ids.get(s) {
            return Symbol(id);
        }
        let leaked: &'static str = Box::leak(s.to_owned().into_boxed_str());
        let id = t.names.len() as u32;
        t.names.push(leaked);
        t.ids.insert(leaked, id);
        Symbol(id)
    }

    /// A globally-fresh symbol `<prefix><n>`; used for schedule loop
    /// variables introduced by rewrites (capture-free by construction).
    pub fn fresh(prefix: &str) -> Self {
        let n = FRESH.fetch_add(1, Ordering::Relaxed);
        Symbol::new(&format!("{prefix}{n}"))
    }

    /// The interned string.
    pub fn as_str(&self) -> &'static str {
        interner().lock().unwrap().names[self.0 as usize]
    }
}

impl fmt::Display for Symbol {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

impl fmt::Debug for Symbol {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.as_str())
    }
}

impl From<&str> for Symbol {
    fn from(s: &str) -> Self {
        Symbol::new(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn intern_is_idempotent() {
        assert_eq!(Symbol::new("x"), Symbol::new("x"));
        assert_ne!(Symbol::new("x"), Symbol::new("y"));
    }

    #[test]
    fn roundtrips_text() {
        assert_eq!(Symbol::new("conv1_weight").as_str(), "conv1_weight");
    }

    #[test]
    fn fresh_symbols_are_distinct() {
        let a = Symbol::fresh("i");
        let b = Symbol::fresh("i");
        assert_ne!(a, b);
        assert!(a.as_str().starts_with('i'));
    }

    #[test]
    fn display_matches_str() {
        let s = Symbol::new("hello");
        assert_eq!(format!("{s}"), "hello");
    }
}
