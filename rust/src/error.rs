//! The crate-wide typed error. Every fallible API boundary — parsing,
//! lowering, rule lookup, session building, query evaluation, backend
//! execution — returns [`Error`] instead of panicking, so library callers
//! (the CLI, a serving loop, tests) can handle bad input without aborting
//! the process.

use crate::ir::parse::ParseError;
use crate::ir::TypeError;
use crate::tensor::EvalError;
use std::fmt;

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// All the ways the public API can fail.
#[derive(Debug, Clone)]
pub enum Error {
    /// EngineIR text failed to parse.
    Parse(ParseError),
    /// An expression failed shape/type inference.
    Type(TypeError),
    /// Concrete evaluation failed (unbound tensor, backend failure, …).
    Eval(EvalError),
    /// A rewrite-rule name did not resolve (CLI `--rules a,b,c`).
    UnknownRule(String),
    /// A rule-set name did not resolve (`fig2` / `paper` / `all`).
    UnknownRuleSet(String),
    /// A rule-scheduler name did not resolve (`simple` / `backoff`).
    UnknownScheduler(String),
    /// A workload name did not resolve.
    UnknownWorkload(String),
    /// A backend name did not resolve (`analytic` / `interp` / `sim` / `pjrt`).
    UnknownBackend(String),
    /// Reification hit a structurally invalid input (e.g. a non-tensor
    /// child where the lowering rules require one).
    Lower { op: String, detail: String },
    /// A session was configured inconsistently (missing workload, zero
    /// samples where designs were requested, …).
    InvalidConfig(String),
    /// An evaluation backend failed or is not compiled into this build.
    Backend { backend: &'static str, detail: String },
    /// The requested operation needs a feature this build lacks
    /// (e.g. `pjrt`).
    Unsupported(String),
    /// An underlying I/O operation failed (snapshot read/write, serving
    /// socket). Stores the rendered `std::io::Error` — the crate error is
    /// `Clone` and `io::Error` is not.
    Io(String),
    /// A snapshot file carries a format version this build cannot read.
    SnapshotVersion { found: u32, supported: u32 },
    /// A snapshot file is structurally invalid: bad magic, truncated,
    /// checksum mismatch, or an undecodable payload.
    SnapshotCorrupt(String),
    /// The serving daemon's bounded pending queue is full — typed
    /// backpressure instead of unbounded queueing. Carries the queue
    /// occupancy at refusal time and a retry hint derived from observed
    /// service latency (also sent on the wire as `retry_after_ms`).
    Busy { queued: usize, retry_after_ms: u64 },
    /// A request ran past its deadline (`--request-timeout-ms`). `phase`
    /// names the pipeline stage whose cooperative check observed it.
    Timeout { phase: &'static str },
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Parse(e) => write!(f, "{e}"),
            Error::Type(e) => write!(f, "type error: {e}"),
            Error::Eval(e) => write!(f, "evaluation error: {e}"),
            Error::UnknownRule(n) => write!(
                f,
                "unknown rewrite rule '{n}' (see rewrites::all_rules for valid names)"
            ),
            Error::UnknownRuleSet(n) => {
                write!(f, "unknown rule set '{n}' (expected fig2 | paper | all)")
            }
            Error::UnknownScheduler(n) => {
                write!(f, "unknown scheduler '{n}' (expected simple | backoff)")
            }
            Error::UnknownWorkload(n) => {
                write!(
                    f,
                    "unknown workload '{n}' (available: {})",
                    crate::relay::known_workload_names().join(" | ")
                )
            }
            Error::UnknownBackend(n) => write!(
                f,
                "unknown backend '{n}' (expected analytic | interp | sim | pjrt)"
            ),
            Error::Lower { op, detail } => write!(f, "lowering {op}: {detail}"),
            Error::InvalidConfig(msg) => write!(f, "invalid configuration: {msg}"),
            Error::Backend { backend, detail } => write!(f, "{backend} backend: {detail}"),
            Error::Unsupported(msg) => write!(f, "unsupported: {msg}"),
            Error::Io(msg) => write!(f, "i/o error: {msg}"),
            Error::SnapshotVersion { found, supported } => write!(
                f,
                "snapshot format version {found} is not readable by this build \
                 (supports version {supported}); re-save the snapshot"
            ),
            Error::SnapshotCorrupt(msg) => write!(f, "corrupt snapshot: {msg}"),
            Error::Busy { queued, retry_after_ms } => write!(
                f,
                "server busy: {queued} connections pending; retry in ~{retry_after_ms} ms"
            ),
            Error::Timeout { phase } => {
                write!(f, "request deadline exceeded (observed in {phase})")
            }
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Parse(e) => Some(e),
            Error::Type(e) => Some(e),
            Error::Eval(e) => Some(e),
            _ => None,
        }
    }
}

impl From<ParseError> for Error {
    fn from(e: ParseError) -> Self {
        Error::Parse(e)
    }
}

impl From<TypeError> for Error {
    fn from(e: TypeError) -> Self {
        Error::Type(e)
    }
}

impl From<EvalError> for Error {
    fn from(e: EvalError) -> Self {
        Error::Eval(e)
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn displays_are_informative() {
        let e = Error::UnknownRuleSet("bogus".into());
        assert!(e.to_string().contains("bogus"));
        assert!(e.to_string().contains("fig2"));
        let e = Error::Backend { backend: "pjrt", detail: "no artifacts".into() };
        assert!(e.to_string().contains("pjrt"));
    }

    #[test]
    fn unknown_workload_lists_every_available_name() {
        let msg = Error::UnknownWorkload("lemon".into()).to_string();
        assert!(msg.contains("lemon"));
        for name in crate::relay::workload_names() {
            assert!(msg.contains(name), "missing '{name}' in: {msg}");
        }
    }

    #[test]
    fn unknown_workload_suggests_registered_workloads_too() {
        let mut b = crate::relay::GraphBuilder::new();
        let x = b.input("x", &[4]);
        b.relu(x);
        crate::relay::register_workload(crate::relay::Workload {
            name: "err_test_imported_wl".to_string(),
            description: "registered for the suggestion-list test".to_string(),
            expr: b.finish(),
        });
        let msg = Error::UnknownWorkload("lemon".into()).to_string();
        assert!(msg.contains("err_test_imported_wl"), "{msg}");
    }

    #[test]
    fn io_errors_convert_and_display() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "no such snapshot");
        let e: Error = io.into();
        assert!(matches!(e, Error::Io(_)));
        assert!(e.to_string().contains("no such snapshot"), "{e}");
        assert!(e.to_string().contains("i/o error"), "{e}");
    }

    #[test]
    fn snapshot_version_names_both_versions() {
        let msg = Error::SnapshotVersion { found: 9, supported: 1 }.to_string();
        assert!(msg.contains('9'), "{msg}");
        assert!(msg.contains('1'), "{msg}");
        assert!(msg.contains("version"), "{msg}");
    }

    #[test]
    fn snapshot_corrupt_carries_detail() {
        let msg = Error::SnapshotCorrupt("checksum mismatch at byte 12".into()).to_string();
        assert!(msg.contains("corrupt snapshot"), "{msg}");
        assert!(msg.contains("checksum mismatch"), "{msg}");
    }

    #[test]
    fn busy_carries_queue_depth_and_retry_hint() {
        let msg = Error::Busy { queued: 7, retry_after_ms: 120 }.to_string();
        assert!(msg.contains("busy"), "{msg}");
        assert!(msg.contains('7'), "{msg}");
        assert!(msg.contains("120"), "{msg}");
    }

    #[test]
    fn timeout_names_the_observing_phase() {
        let msg = Error::Timeout { phase: "extract" }.to_string();
        assert!(msg.contains("deadline"), "{msg}");
        assert!(msg.contains("extract"), "{msg}");
    }

    #[test]
    fn wraps_parse_and_type_errors_with_source() {
        use std::error::Error as _;
        let p: Error = crate::ir::parse_expr("(frobnicate)").unwrap_err().into();
        assert!(p.source().is_some());
        assert!(p.to_string().contains("parse error"));
    }
}
