//! A cycle-approximate accelerator simulator: the "usefulness" oracle.
//!
//! The analytic model in [`crate::cost`] prices a design by composition;
//! this simulator actually *plays the schedule out* over a finite pool of
//! engine instances with availability-based contention, which is what a
//! real accelerator with shared engines experiences. Where the analytic
//! model assumes a `sched-par` always has enough hardware, the simulator
//! derives the physical instance pool from the design (same replication
//! rule) and then list-schedules every invocation onto the earliest
//! available instance — so engine sharing across *sibling* parallel
//! branches is modelled faithfully, including the serialization it causes.
//!
//! The simulator also reports per-engine busy cycles and overall
//! utilization: the paper's "useful design" (one that "could turn into
//! efficient hardware") is, concretely, a design whose engines are neither
//! idle (wasted area) nor serializing everything (wasted time).

use crate::cost::{engine_cycles, CostParams};
use crate::ir::{BufKind, Op, RecExpr, Shape, Ty};
use std::collections::HashMap;

/// Cap on physical instances per engine declaration. Nested `sched-par`
/// extents multiply, and sampled designs can demand astronomically many
/// engines (a fully spatial design is *representable* even when absurd);
/// beyond this cap the pool saturates and extra parallel branches simply
/// contend — which is also what any real substrate would do.
pub const MAX_INSTANCES: usize = 4096;

/// Simulation configuration.
#[derive(Debug, Clone, Default)]
pub struct SimConfig {
    pub params: CostParams,
}

/// Result of simulating one inference.
#[derive(Debug, Clone)]
pub struct SimReport {
    /// Makespan in cycles.
    pub cycles: f64,
    /// Number of engine invocations executed.
    pub invocations: usize,
    /// Busy cycles per engine declaration.
    pub engine_busy: HashMap<Op, f64>,
    /// Instances per engine declaration (the physical pool).
    pub engine_instances: HashMap<Op, usize>,
    /// Aggregate utilization: busy / (makespan × total instances).
    pub utilization: f64,
    /// Total SRAM bytes allocated to buffers.
    pub sram_bytes: f64,
    /// Total DRAM element traffic.
    pub dram_traffic: f64,
}

impl SimReport {
    /// Compact single-line summary.
    pub fn line(&self) -> String {
        format!(
            "cycles={:.0} invokes={} engines={} util={:.1}% sram={:.0}B dram={:.0}",
            self.cycles,
            self.invocations,
            self.engine_instances.len(),
            self.utilization * 100.0,
            self.sram_bytes,
            self.dram_traffic
        )
    }
}

struct Sim<'a> {
    expr: &'a RecExpr,
    tys: Vec<Ty>,
    p: CostParams,
    /// engine decl -> per-instance next-free time
    pools: HashMap<Op, Vec<f64>>,
    busy: HashMap<Op, f64>,
    invocations: usize,
    sram_bytes: f64,
    dram_traffic: f64,
    /// Per-slot free loop variables (loop-invariant subtrees run once).
    free: Vec<Vec<crate::ir::Symbol>>,
    /// Completion time of already-materialized loop-invariant subtrees.
    done: Vec<Option<f64>>,
    /// size_pools visited set (slot, par_mult) to stay linear on DAGs.
    sized: std::collections::HashSet<(usize, usize)>,
}

impl<'a> Sim<'a> {
    fn shape(&self, id: crate::egraph::Id) -> &Shape {
        match &self.tys[id.index()] {
            Ty::Tensor(s) => s,
            _ => panic!("sim: expected tensor"),
        }
    }

    /// Pre-pass: derive the physical instance pool (max parallel demand per
    /// engine declaration — the same rule the area model charges for).
    fn size_pools(&mut self, id: crate::egraph::Id, par_mult: usize) {
        // Loop-invariant subtrees materialize once: one instance suffices
        // no matter how parallel the consumer is.
        let par_mult = if self.free[id.index()].is_empty() { 1 } else { par_mult };
        if !self.sized.insert((id.index(), par_mult)) {
            return;
        }
        let node = self.expr.node(id).clone();
        match &node.op {
            op if op.is_invoke() => {
                let engine = self.expr.node(node.children[0]).op.clone();
                let want = par_mult.min(MAX_INSTANCES);
                let e = self.pools.entry(engine).or_default();
                if e.len() < want {
                    e.resize(want, 0.0);
                }
                for &a in &node.children[1..] {
                    self.size_pools(a, par_mult);
                }
            }
            Op::SchedPar { extent, .. } => self.size_pools(
                node.children[0],
                par_mult.saturating_mul(*extent).min(MAX_INSTANCES),
            ),
            _ => {
                for &c in &node.children {
                    self.size_pools(c, par_mult);
                }
            }
        }
    }

    /// Simulate the subtree starting at time `t0`; returns completion time.
    /// Loop-invariant subtrees run once (the producer materializes into its
    /// buffer); later consumers wait on the recorded completion time. This
    /// both matches real dataflow and keeps the walk linear — naively
    /// re-simulating a producer per consumer-loop iteration compounds
    /// exponentially across layers.
    fn run(&mut self, id: crate::egraph::Id, t0: f64) -> f64 {
        let slot = id.index();
        if self.free[slot].is_empty() {
            if let Some(t) = self.done[slot] {
                return t0.max(t);
            }
            let t = self.run_node(id, t0);
            self.done[slot] = Some(t);
            return t;
        }
        self.run_node(id, t0)
    }

    /// Dispatch is by registry class (like the analytic model): open
    /// categories price themselves from their spec, so new ops need no arm.
    fn run_node(&mut self, id: crate::egraph::Id, t0: f64) -> f64 {
        let node = self.expr.node(id).clone();
        let c = &node.children;
        let spec = node.op.spec();
        match &node.op {
            op if matches!(
                op.class(),
                crate::ir::OpClass::Index | crate::ir::OpClass::Leaf | crate::ir::OpClass::Engine
            ) =>
            {
                t0
            }

            op if op.is_invoke() => {
                // Operands must be ready first.
                let mut ready = t0;
                let mut io: f64 = self.shape(id).numel() as f64;
                for &arg in &c[1..] {
                    ready = self.run(arg, ready);
                    io += self.shape(arg).numel() as f64;
                }
                let engine = self.expr.node(c[0]).op.clone();
                let dur = engine_cycles(&engine, io, &self.p);
                // Acquire the earliest-free instance.
                let pool = self.pools.get_mut(&engine).expect("pool sized");
                let (idx, free_at) = pool
                    .iter()
                    .enumerate()
                    .map(|(i, &t)| (i, t))
                    .min_by(|a, b| a.1.total_cmp(&b.1))
                    .expect("nonempty pool");
                let start = ready.max(free_at);
                pool[idx] = start + dur;
                *self.busy.entry(engine).or_insert(0.0) += dur;
                self.invocations += 1;
                start + dur
            }

            Op::SchedLoop { extent, .. } => {
                let mut t = t0;
                for _ in 0..*extent {
                    t = self.run(c[0], t + self.p.loop_overhead);
                }
                t
            }
            Op::SchedPar { extent, .. } => {
                let mut t_end = t0;
                for _ in 0..*extent {
                    // All branches *start* at t0; engine contention is
                    // resolved by the instance pool.
                    t_end = t_end.max(self.run(c[0], t0));
                }
                t_end + (*extent as f64).log2().ceil() * self.p.loop_overhead
            }
            Op::SchedReduce { extent, .. } => {
                let out = self.shape(id).numel() as f64;
                let acc = out / self.p.port_width;
                let mut t = t0;
                for i in 0..*extent {
                    t = self.run(c[0], t + self.p.loop_overhead);
                    if i > 0 {
                        t += acc;
                    }
                }
                t
            }

            // Data movement: views are free; materializing transforms
            // (pad2d/im2col/transpose) pay SRAM traffic. Index children
            // cost nothing.
            op if matches!(op.class(), crate::ir::OpClass::Data) => {
                let mut t = t0;
                for &arg in c {
                    t = self.run(arg, t);
                }
                if spec.data_traffic {
                    t + self.shape(id).numel() as f64 / self.p.sram_bw
                } else {
                    t
                }
            }
            Op::Buffer { kind } | Op::DblBuffer { kind } => {
                let elems = self.shape(id).numel() as f64;
                let dbl = matches!(node.op, Op::DblBuffer { .. });
                let t = self.run(c[0], t0);
                match kind {
                    BufKind::Sram => {
                        self.sram_bytes += elems * 4.0 * if dbl { 2.0 } else { 1.0 };
                        t + (if dbl { 1.0 } else { 2.0 }) * elems / self.p.sram_bw
                    }
                    BufKind::Dram => {
                        self.dram_traffic += 2.0 * elems;
                        t + (if dbl { 1.0 } else { 2.0 }) * elems / self.p.dram_bw
                    }
                }
            }

            // Un-reified Relay op: host fallback, same work model as the
            // analytic cost (the op's spec `host_work`).
            op => {
                let mut t = t0;
                for &arg in c {
                    t = self.run(arg, t);
                }
                let out = self.shape(id).clone();
                let child_shapes: Vec<&Shape> = c.iter().map(|&a| self.shape(a)).collect();
                let work = match spec.host_work {
                    Some(f) => f(op, &out, &child_shapes),
                    None => out.numel() as f64,
                };
                t + work * self.p.host_penalty
            }
        }
    }
}

/// Simulate one inference of `expr`.
pub fn simulate(expr: &RecExpr, cfg: &SimConfig) -> SimReport {
    let tys = expr.types().expect("sim: design must be well-typed");
    let mut sim = Sim {
        expr,
        tys,
        p: cfg.params.clone(),
        pools: HashMap::new(),
        busy: HashMap::new(),
        invocations: 0,
        sram_bytes: 0.0,
        dram_traffic: 0.0,
        free: expr.free_lvars(),
        done: vec![None; expr.len()],
        sized: std::collections::HashSet::new(),
    };
    sim.size_pools(expr.root(), 1);
    let cycles = sim.run(expr.root(), 0.0);
    let total_instances: usize = sim.pools.values().map(|v| v.len()).sum();
    let total_busy: f64 = sim.busy.values().sum();
    let utilization = if cycles > 0.0 && total_instances > 0 {
        (total_busy / (cycles * total_instances as f64)).min(1.0)
    } else {
        0.0
    };
    SimReport {
        cycles,
        invocations: sim.invocations,
        engine_busy: sim.busy,
        engine_instances: sim.pools.into_iter().map(|(k, v)| (k, v.len())).collect(),
        utilization,
        sram_bytes: sim.sram_bytes,
        dram_traffic: sim.dram_traffic,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::cost_of;
    use crate::ir::parse_expr;

    fn sim(src: &str) -> SimReport {
        simulate(&parse_expr(src).unwrap(), &SimConfig::default())
    }

    const WHOLE: &str = "(invoke-relu (relu-engine 128) (input x [128]))";
    const LOOPED: &str = "(sched-loop i0 0 2 (invoke-relu (relu-engine 64) \
        (slice 0 64 (imul (lvar i0) 64) (input x [128]))))";
    const PARRED: &str = "(sched-par i0 0 2 (invoke-relu (relu-engine 64) \
        (slice 0 64 (imul (lvar i0) 64) (input x [128]))))";

    #[test]
    fn fig2_sim_ordering_matches_cost_model() {
        let (w, l, p) = (sim(WHOLE), sim(LOOPED), sim(PARRED));
        assert!(l.cycles > w.cycles, "loop must be slower than big engine");
        assert!(p.cycles < l.cycles, "par must beat loop");
        // Pool sizes: loop has 1 instance, par has 2.
        assert_eq!(l.engine_instances.values().sum::<usize>(), 1);
        assert_eq!(p.engine_instances.values().sum::<usize>(), 2);
    }

    #[test]
    fn sim_agrees_with_analytic_model_on_sequential_designs() {
        for src in [WHOLE, LOOPED] {
            let s = sim(src);
            let c = cost_of(&parse_expr(src).unwrap(), &CostParams::default());
            let ratio = s.cycles / c.latency;
            assert!(
                (0.5..=2.0).contains(&ratio),
                "{src}: sim {} vs analytic {}",
                s.cycles,
                c.latency
            );
        }
    }

    #[test]
    fn par_with_shared_engine_pool_contends() {
        // Two parallel branches but invoking through a *loop inside*: the
        // pool still has 2 instances (par extent), utilization <= 1.
        let r = sim(PARRED);
        assert!(r.utilization > 0.0 && r.utilization <= 1.0);
    }

    #[test]
    fn invocation_counts() {
        assert_eq!(sim(WHOLE).invocations, 1);
        assert_eq!(sim(LOOPED).invocations, 2);
        let nested = "(sched-loop a 0 2 (sched-loop b 0 2 (invoke-relu (relu-engine 32) \
            (slice 0 32 (iadd (imul (lvar a) 64) (imul (lvar b) 32)) (input x [128])))))";
        assert_eq!(sim(nested).invocations, 4);
    }

    #[test]
    fn dram_buffer_traffic_counted() {
        let r = sim("(buffer dram (invoke-relu (relu-engine 16) (input x [16])))");
        assert_eq!(r.dram_traffic, 32.0);
        assert_eq!(r.sram_bytes, 0.0);
    }

    #[test]
    fn deterministic() {
        let a = sim(PARRED);
        let b = sim(PARRED);
        assert_eq!(a.cycles, b.cycles);
    }
}
