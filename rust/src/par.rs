//! The scoped worker pool shared by every read-only fan-out in the crate:
//! the saturation engine's parallel search phase ([`crate::egraph::Runner`])
//! and the session layer's extraction/evaluation fan-out
//! ([`crate::session`]).
//!
//! Deliberately tiny: scoped threads pulling indices off one atomic counter,
//! results written back by input position. No work stealing, no channels —
//! the workloads here are hundreds-to-thousands of near-uniform items, where
//! a shared counter is within noise of a real deque and has nothing to
//! misconfigure. Order preservation is what the callers actually rely on:
//! it is what makes the parallel search phase's merge deterministic.

/// Sensible worker-pool width for this machine: the full
/// `available_parallelism` (every `*-workers` flag defaults through here).
pub fn default_workers() -> usize {
    workers_from(std::thread::available_parallelism().ok())
}

/// [`default_workers`] with the platform probe factored out so the
/// fallback is testable: when the machine's parallelism is unknowable,
/// run serial (1) rather than guessing wider than the hardware.
pub(crate) fn workers_from(probed: Option<std::num::NonZeroUsize>) -> usize {
    probed.map(std::num::NonZeroUsize::get).unwrap_or(1)
}

/// Scoped-thread parallel map preserving input order.
///
/// `workers == 1` (or a single item) runs inline on the caller's thread —
/// same results, no spawn overhead — so callers can pass their configured
/// width unconditionally.
pub fn parallel_map<T: Send + Sync, R: Send>(
    workers: usize,
    items: Vec<T>,
    f: impl Fn(&T) -> R + Sync,
) -> Vec<R> {
    if items.is_empty() {
        return Vec::new();
    }
    let workers = workers.clamp(1, items.len());
    if workers == 1 {
        return items.iter().map(f).collect();
    }
    // Each worker accumulates `(index, result)` pairs locally and hands the
    // batch back through its join handle — no per-item lock on the hot
    // path; the single-threaded merge rebuilds input order.
    let next = std::sync::atomic::AtomicUsize::new(0);
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..workers)
            .map(|_| {
                scope.spawn(|| {
                    let mut local: Vec<(usize, R)> = Vec::new();
                    loop {
                        let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        if i >= items.len() {
                            break;
                        }
                        local.push((i, f(&items[i])));
                    }
                    local
                })
            })
            .collect();
        let mut results: Vec<Option<R>> = items.iter().map(|_| None).collect();
        for h in handles {
            for (i, r) in h.join().expect("worker panicked") {
                results[i] = Some(r);
            }
        }
        results.into_iter().map(|r| r.expect("every index visited")).collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order_across_widths() {
        for workers in [1, 2, 8, 200] {
            let out = parallel_map(workers, (0..100).collect::<Vec<_>>(), |x| x * 2);
            assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
        }
    }

    #[test]
    fn empty_input_is_fine() {
        let out: Vec<i32> = parallel_map(4, Vec::<i32>::new(), |x| *x);
        assert!(out.is_empty());
    }

    #[test]
    fn default_workers_is_positive() {
        assert!(default_workers() >= 1);
    }

    #[test]
    fn unknowable_parallelism_falls_back_to_serial() {
        assert_eq!(workers_from(None), 1);
        assert_eq!(workers_from(std::num::NonZeroUsize::new(8)), 8);
        assert_eq!(workers_from(std::num::NonZeroUsize::new(1)), 1);
    }
}
