//! Quickstart: the paper's Fig. 2, replayed end to end.
//!
//! A single 128-wide ReLU invocation is enumerated with the paper's two
//! rewrites (shrink-engine-add-loop; parallelize-loop-add-hardware); the
//! e-graph then holds the whole time/space-multiplexing spectrum at once.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use hwsplit::cost::{analyze, CostParams};
use hwsplit::egraph::Runner;
use hwsplit::extract::{sample_designs, Extractor};
use hwsplit::ir::parse_expr;
use hwsplit::rewrites;
use hwsplit::tensor::{eval_expr, Env};

fn main() {
    // The Fig. 2 starting point: one invocation of one 128-wide ReLU unit.
    let program = parse_expr("(invoke-relu (relu-engine 128) (input x [128]))").unwrap();
    println!("initial program:\n  {program}\n");

    // Enumerate with the paper's two rewrites.
    let mut runner = Runner::new(program.clone(), rewrites::fig2_rules());
    let report = runner.run(8);
    println!("e-graph growth per rewrite iteration:");
    println!("{}", report.table());

    // Pull out some of the equivalent designs the e-graph now represents.
    let params = CostParams::default();
    let points = sample_designs(&runner.egraph, runner.root, 16, &params);
    println!("{} distinct designs sampled; a few of them:\n", points.len());
    for p in points.iter().take(6) {
        println!("  area={:>8.1} latency={:>7.1}  {}", p.cost.area, p.cost.latency, p.expr);
    }

    // Every design computes the same function (differential check).
    let want = eval_expr(&program, &mut Env::random_for(&program, 7)).unwrap();
    for p in &points {
        let got = eval_expr(&p.expr, &mut Env::random_for(&p.expr, 7)).unwrap();
        assert!(want.allclose(&got, 1e-5), "a sampled design diverged!");
    }
    println!("\nall {} sampled designs are functionally identical ✔", points.len());

    // The two extremes the paper describes: lots of hardware vs deep loops.
    let fast = Extractor::new(&runner.egraph, hwsplit::extract::latency_cost)
        .extract(&runner.egraph, runner.root);
    let small = Extractor::new(&runner.egraph, hwsplit::extract::area_cost)
        .extract(&runner.egraph, runner.root);
    let (cf, _) = analyze(&fast, &params);
    let (cs, _) = analyze(&small, &params);
    println!("\nlatency-optimal: area={:.1} latency={:.1}\n  {fast}", cf.area, cf.latency);
    println!("\narea-optimal:    area={:.1} latency={:.1}\n  {small}", cs.area, cs.latency);
}
