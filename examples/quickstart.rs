//! Quickstart: the paper's Fig. 2 economics through the `Session` API.
//!
//! A single 128-wide ReLU invocation is enumerated **once** with the
//! paper's two rewrites (shrink-engine-add-loop; parallelize-loop-add-
//! hardware); the session then answers several different queries — fastest
//! design, smallest design, simulator-checked designs, functionally-checked
//! designs — against the same cached e-graph.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use hwsplit::prelude::*;

fn main() -> hwsplit::Result<()> {
    // The Fig. 2 starting point: one invocation of one 128-wide ReLU unit.
    let w = workloads::relu128();
    println!("workload:\n  {}\n", w.expr);

    // Build the session: lowering happens now, enumeration lazily on the
    // first query.
    let mut session = Session::builder().workload(w.clone()).rules(RuleSet::Fig2).build()?;

    // Query 1 — fastest design (enumerates the e-graph, once).
    let fast = session.query(&Query::new().objective(Objective::Latency).samples(16))?;
    let best_fast = fast.best().expect("nonempty space");
    println!(
        "latency-optimal: area={:>8.1} latency={:>7.1}\n  {}\n",
        best_fast.point.cost.area, best_fast.point.cost.latency, best_fast.point.expr
    );

    // Query 2 — smallest design. Same e-graph, no re-enumeration.
    let small = session.query(&Query::new().objective(Objective::Area).samples(16))?;
    let best_small = small.best().expect("nonempty space");
    println!(
        "area-optimal:    area={:>8.1} latency={:>7.1}\n  {}\n",
        best_small.point.cost.area, best_small.point.cost.latency, best_small.point.expr
    );

    // Query 3 — the simulator backend plays each schedule out over a
    // finite engine pool.
    let simmed = session.query(&Query::new().backend(Backend::Sim).samples(16))?;
    println!("{} designs under the simulator; a few of them:", simmed.designs.len());
    for d in simmed.designs.iter().take(6) {
        let sim = d.sim.as_ref().expect("sim backend reports");
        println!(
            "  area={:>8.1} latency={:>7.1} sim-cycles={:>7.0} util={:>3.0}%  {}",
            d.point.cost.area,
            d.point.cost.latency,
            sim.cycles,
            sim.utilization * 100.0,
            d.point.expr
        );
    }

    // Query 4 — the interpreter backend produces functional outputs;
    // every design must compute the same function as the workload.
    let checked = session.query(&Query::new().backend(Backend::Interp).samples(16))?;
    let want = hwsplit::tensor::eval_expr(
        &w.expr,
        &mut hwsplit::tensor::Env::random_for(&w.expr, 0),
    )?;
    for d in &checked.designs {
        let got = d.output.as_ref().expect("interp backend outputs");
        assert!(want.allclose(got, 1e-5), "a sampled design diverged!");
    }
    println!(
        "\nall {} designs are functionally identical ✔ (checked on the interp backend)",
        checked.designs.len()
    );

    // The load-bearing property: four queries, one enumeration.
    assert_eq!(session.enumeration_count(), 1);
    println!("queries answered: 4; enumerations paid: {}", session.enumeration_count());
    println!("\n{}", simmed.frontier_vs_baseline());
    Ok(())
}
