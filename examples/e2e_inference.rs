//! End-to-end driver (experiment E5): prove all three layers compose.
//!
//! 1. An `mlp` `Session` reifies the workload and enumerates its design
//!    space (once); the initial design and a rewritten (split) variant are
//!    extracted from the session's e-graph;
//! 2. both designs execute **on the PJRT runtime**: every engine
//!    invocation runs an AOT-compiled Pallas kernel (Layer 1) loaded from
//!    `artifacts/` (built once by `make artifacts`); the software schedule
//!    — slices, loops, buffers — runs in Rust (Layer 3);
//! 3. results are validated against the pure-Rust oracle, and a small
//!    batched workload reports latency/throughput.
//!
//! Needs a `--features pjrt` build; the default (stub) build and missing
//! artifacts both exit gracefully with the typed error.
//!
//! ```sh
//! make artifacts && cargo run --release --features pjrt --example e2e_inference
//! ```

use hwsplit::prelude::*;
use hwsplit::extract::sample_design;
use hwsplit::runtime::{default_artifact_dir, extract_covered, EngineRuntime, PjrtBackend};
use hwsplit::tensor::{eval_expr, eval_expr_backend, Env, Tensor};
use std::time::Instant;

fn main() -> hwsplit::Result<()> {
    let rt = match EngineRuntime::new(default_artifact_dir()) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("cannot open the PJRT runtime ({e}); run `make artifacts` and build \
                       with --features pjrt (requires vendoring the `xla` crate — see \
                       Cargo.toml)");
            std::process::exit(2);
        }
    };
    println!("artifact library: {} engines available", rt.available().len());

    let mut session =
        Session::builder().workload(workloads::mlp()).rules(RuleSet::Paper).iters(4).build()?;
    let initial = session.lowered().clone();

    // Find a *rewritten* design whose engines are all in the library:
    // constrained extraction over the session's e-graph (prohibitive cost
    // on uncovered engines), leaning small so the design genuinely uses
    // schedules; fall back to random samples if the greedy pick has no
    // schedule.
    let en = session.enumerate()?;
    let mut split: Option<RecExpr> = extract_covered(&en.egraph, en.root, &rt, true)
        .filter(|d| d.count(|op| op.is_sched()) > 0);
    if split.is_none() {
        for seed in 0..400u64 {
            let cand = sample_design(&en.egraph, en.root, seed);
            if cand.count(|op| op.is_sched()) > 0
                && cand.engines().iter().all(|e| rt.has_engine(e))
            {
                split = Some(cand);
                break;
            }
        }
    }

    let mut backend = PjrtBackend::new(rt);
    for (name, design) in [("initial", Some(initial)), ("rewritten", split)] {
        let Some(design) = design else {
            println!("({name}: no artifact-covered split design found, skipping)");
            continue;
        };
        println!("\n== {name} design: {} nodes, engines:", design.len());
        for e in design.engines() {
            println!("     {e}");
        }

        // Correctness: PJRT vs oracle on one input.
        let env0 = Env::random_for(&design, 42);
        let want = eval_expr(&design, &mut env0.clone())?;
        let got = eval_expr_backend(&design, &mut env0.clone(), &mut backend)?;
        let diff = got.max_abs_diff(&want).unwrap();
        println!("   max |PJRT - oracle| = {diff:.3e}");
        assert!(diff < 1e-3, "numerics diverged");

        // Throughput: a small batch of inferences (weights stay bound,
        // input varies), as a server loop would run it.
        let batch = 32;
        let t0 = Instant::now();
        let mut checksum = 0.0f32;
        for i in 0..batch {
            let mut env = env0.clone();
            env.bind("x", Tensor::random(hwsplit::ir::Shape::new(&[1, 784]), 1000 + i));
            let out = eval_expr_backend(&design, &mut env, &mut backend)?;
            checksum += out.data.iter().sum::<f32>();
        }
        let dt = t0.elapsed();
        println!(
            "   {batch} inferences in {:.2?} -> {:.1} inf/s (mean latency {:.2?}); checksum {checksum:.3}",
            dt,
            batch as f64 / dt.as_secs_f64(),
            dt / batch as u32,
        );
    }
    println!(
        "\nPJRT calls: {} (oracle fallbacks: {}); executables compiled: {}",
        backend.pjrt_calls,
        backend.oracle_calls,
        backend.runtime.compiled()
    );
    println!("e2e OK");
    Ok(())
}
