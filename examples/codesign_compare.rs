//! Codesign comparison (experiment E3, across the whole workload library):
//! enumerated hardware–software splits vs the related-work baseline of one
//! engine per kernel type (Hadjis & Olukotun, FPL'19 — the paper's §4).
//!
//! For each workload, prints the baseline point and the best enumerated
//! design at (a) the baseline's area budget and (b) unlimited area — the
//! concrete version of the paper's claim that rewriting finds "more
//! complex (but potentially more profitable) splits". Each workload gets
//! one `Session`: the latency-leaning and area-leaning questions are two
//! queries over the same enumeration.
//!
//! ```sh
//! cargo run --release --example codesign_compare
//! ```

use hwsplit::prelude::*;
use hwsplit::relay::all_workloads;
use hwsplit::report::{fmt_f64, Table};

fn main() -> hwsplit::Result<()> {
    let mut t = Table::new(
        "enumerated splits vs one-engine-per-kernel-type baseline",
        &[
            "workload",
            "base-area",
            "base-lat",
            "best-lat@base-area",
            "speedup",
            "best-lat-any",
            "min-area(<=base-lat)",
            "area-ratio",
        ],
    );

    for w in all_workloads() {
        let mut session = Session::builder()
            .workload(w.clone())
            .rules(RuleSet::Paper)
            .iters(5)
            .limits(RunnerLimits { max_nodes: 50_000, ..Default::default() })
            .build()?;
        // Two objectives, one enumeration.
        let fast = session.query(&Query::new().objective(Objective::Latency).samples(48))?;
        let small = session.query(&Query::new().objective(Objective::Area).samples(48))?;
        assert_eq!(session.enumeration_count(), 1);
        let b = &fast.baseline.cost;

        // Best latency among designs within the baseline's area budget.
        let within = fast
            .designs
            .iter()
            .filter(|d| d.point.cost.area <= b.area * 1.0001)
            .map(|d| d.point.cost.latency)
            .fold(f64::INFINITY, f64::min);
        // Best latency anywhere.
        let best = fast
            .designs
            .iter()
            .map(|d| d.point.cost.latency)
            .fold(f64::INFINITY, f64::min);
        // Smallest area at baseline-or-better latency.
        let min_area = small
            .designs
            .iter()
            .filter(|d| d.point.cost.latency <= b.latency * 1.0001)
            .map(|d| d.point.cost.area)
            .fold(f64::INFINITY, f64::min);

        t.row(&[
            w.name.to_string(),
            fmt_f64(b.area),
            fmt_f64(b.latency),
            fmt_f64(within),
            if within.is_finite() { format!("{:.2}x", b.latency / within) } else { "-".into() },
            fmt_f64(best),
            fmt_f64(min_area),
            if min_area.is_finite() {
                format!("{:.2}x", b.area / min_area)
            } else {
                "-".into()
            },
        ]);
    }
    print!("{}", t.render());
    println!(
        "\nspeedup  = baseline latency / best enumerated latency at the same area budget\n\
         area-ratio = baseline area / smallest enumerated area at the same latency"
    );
    Ok(())
}
