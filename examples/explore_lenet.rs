//! Full design-space exploration of the LeNet workload: the paper's §3
//! evaluation methodology made concrete.
//!
//! Pipeline: Relay graph → EngineIR reification → rewrite enumeration →
//! diverse design sampling → analytic + simulated evaluation on a worker
//! pool → Pareto frontier vs the one-engine-per-kernel-type baseline.
//!
//! ```sh
//! cargo run --release --example explore_lenet
//! ```

use hwsplit::coordinator::{explore, ExploreConfig, RuleSet};
use hwsplit::egraph::RunnerLimits;
use hwsplit::relay::workloads;
use hwsplit::report::{fmt_f64, Table};

fn main() {
    let w = workloads::lenet();
    let cfg = ExploreConfig {
        iters: 5,
        samples: 48,
        rules: RuleSet::Paper,
        limits: RunnerLimits { max_nodes: 60_000, ..Default::default() },
        ..Default::default()
    };
    println!("exploring `{}` ({} Relay ops) with {:?} rules…\n", w.name, w.expr.len(), cfg.rules);
    let ex = explore(&w, &cfg);

    println!("enumeration:");
    println!("{}", ex.report.table());

    // Diversity: the structural spread of the sampled designs (E2).
    let mut t = Table::new(
        "design diversity (E2)",
        &["origin", "engines", "instances", "invokes", "depth", "loops", "pars", "bufKB"],
    );
    for d in &ex.designs {
        let s = &d.point.stats;
        t.row(&[
            d.point.origin.clone(),
            s.engines.to_string(),
            format!("{:.0}", s.engine_instances),
            s.invokes.to_string(),
            s.sched_depth.to_string(),
            s.loops.to_string(),
            s.pars.to_string(),
            format!("{:.1}", s.buffer_bytes / 1024.0),
        ]);
    }
    print!("{}", t.render());

    // Mean pairwise distance — one number for "how diverse".
    let pts = &ex.designs;
    let mut dist = 0.0;
    let mut n = 0;
    for i in 0..pts.len() {
        for j in i + 1..pts.len() {
            dist += pts[i].point.stats.distance(&pts[j].point.stats);
            n += 1;
        }
    }
    println!("mean pairwise design distance: {:.3}\n", dist / n.max(1) as f64);

    // Usefulness: Pareto frontier vs baseline (E3).
    let mut f = Table::new(
        "Pareto frontier vs one-engine-per-kernel-type baseline (E3)",
        &["design", "area", "latency", "sim-cycles", "util%"],
    );
    for p in &ex.frontier {
        let sim = ex
            .designs
            .iter()
            .find(|d| d.point.origin == p.origin)
            .map(|d| (d.sim.cycles, d.sim.utilization));
        f.row(&[
            p.origin.clone(),
            fmt_f64(p.cost.area),
            fmt_f64(p.cost.latency),
            sim.map(|s| fmt_f64(s.0)).unwrap_or_default(),
            sim.map(|s| format!("{:.0}", s.1 * 100.0)).unwrap_or_default(),
        ]);
    }
    f.row(&[
        "BASELINE (FPL'19)".into(),
        fmt_f64(ex.baseline.cost.area),
        fmt_f64(ex.baseline.cost.latency),
        String::new(),
        String::new(),
    ]);
    print!("{}", f.render());
    println!("{}", ex.frontier_vs_baseline());
}
