//! Full design-space exploration of the LeNet workload: the paper's §3
//! evaluation methodology made concrete, on the `Session` API.
//!
//! Pipeline: Relay graph → EngineIR reification → rewrite enumeration
//! (once) → per-query diverse design sampling → evaluation on the chosen
//! backend over a worker pool → Pareto frontier vs the
//! one-engine-per-kernel-type baseline.
//!
//! ```sh
//! cargo run --release --example explore_lenet
//! ```

use hwsplit::prelude::*;
use hwsplit::report::{fmt_f64, Table};

fn main() -> hwsplit::Result<()> {
    let w = workloads::lenet();
    println!("exploring `{}` ({} Relay ops)…\n", w.name, w.expr.len());
    let mut session = Session::builder()
        .workload(w)
        .rules(RuleSet::Paper)
        .iters(5)
        .limits(RunnerLimits { max_nodes: 60_000, ..Default::default() })
        .build()?;

    // One simulator-backed query drives both experiment tables below.
    let ev = session.query(&Query::new().backend(Backend::Sim).samples(48))?;

    println!("enumeration:");
    println!("{}", session.enumerate()?.report.table());

    // Diversity: the structural spread of the sampled designs (E2).
    let mut t = Table::new(
        "design diversity (E2)",
        &["origin", "engines", "instances", "invokes", "depth", "loops", "pars", "bufKB"],
    );
    for d in &ev.designs {
        let s = &d.point.stats;
        t.row(&[
            d.point.origin.clone(),
            s.engines.to_string(),
            format!("{:.0}", s.engine_instances),
            s.invokes.to_string(),
            s.sched_depth.to_string(),
            s.loops.to_string(),
            s.pars.to_string(),
            format!("{:.1}", s.buffer_bytes / 1024.0),
        ]);
    }
    print!("{}", t.render());

    // Mean pairwise distance — one number for "how diverse".
    let pts = &ev.designs;
    let mut dist = 0.0;
    let mut n = 0;
    for i in 0..pts.len() {
        for j in i + 1..pts.len() {
            dist += pts[i].point.stats.distance(&pts[j].point.stats);
            n += 1;
        }
    }
    println!("mean pairwise design distance: {:.3}\n", dist / n.max(1) as f64);

    // Usefulness: Pareto frontier vs baseline (E3).
    let mut f = Table::new(
        "Pareto frontier vs one-engine-per-kernel-type baseline (E3)",
        &["design", "area", "latency", "sim-cycles", "util%"],
    );
    for p in &ev.frontier {
        let sim = ev
            .designs
            .iter()
            .find(|d| d.point.origin == p.origin)
            .and_then(|d| d.sim.as_ref())
            .map(|s| (s.cycles, s.utilization));
        f.row(&[
            p.origin.clone(),
            fmt_f64(p.cost.area),
            fmt_f64(p.cost.latency),
            sim.map(|s| fmt_f64(s.0)).unwrap_or_default(),
            sim.map(|s| format!("{:.0}", s.1 * 100.0)).unwrap_or_default(),
        ]);
    }
    f.row(&[
        "BASELINE (FPL'19)".into(),
        fmt_f64(ev.baseline.cost.area),
        fmt_f64(ev.baseline.cost.latency),
        String::new(),
        String::new(),
    ]);
    print!("{}", f.render());
    println!("{}", ev.frontier_vs_baseline());

    // A second scenario against the same enumeration: what would the
    // frontier look like on a bandwidth-starved substrate? Only
    // extraction+evaluation re-run — the e-graph is reused.
    let starved = CostParams { dram_bw: 1.0, sram_bw: 8.0, ..Default::default() };
    let ev2 = session.query(&Query::new().samples(48).params(starved))?;
    println!(
        "\nbandwidth-starved scenario (same e-graph, {} enumeration(s) total): {}",
        session.enumeration_count(),
        ev2.frontier_vs_baseline()
    );
    Ok(())
}
